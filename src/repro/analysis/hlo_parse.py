"""Optimized-HLO text analyzer with call-graph trip-count multipliers.

``jax.stages.Compiled.cost_analysis()`` visits each computation once — a
``lax.scan`` over 60 layers under-reports FLOPs by 60x (verified
empirically; see EXPERIMENTS.md §Dry-run notes).  This module re-derives the
three roofline inputs from ``compiled.as_text()`` instead:

  * FLOPs      — exact for dot-general (2 * prod(out) * prod(contract)),
                 1/elem for elementwise arithmetic and reduces;
  * HBM bytes  — a **TPU-fusion-optimistic traffic model**: we compile with
                 the CPU backend, whose fusion regions are far smaller than
                 TPU's, so fusion-boundary bytes over-count TPU HBM traffic
                 ~100x (measured on smollm train_4k).  Instead we count
                 bytes only where a TPU must touch HBM: dot/convolution
                 operands + results (weights re-read per invocation), pure
                 data-movement ops (slice/gather/scatter/sort/transpose
                 results — layer-boundary activation traffic in scan bodies
                 arrives here via dynamic-(update-)slice), and collective
                 results.  Elementwise/reduce chains are assumed fused.
  * collective bytes — result sizes of all-gather / all-reduce /
                 reduce-scatter / all-to-all / collective-permute ops, split
                 into intra-pod and cross-pod (device id >= pod size).

Every computation's cost is multiplied up the call graph: while bodies by
their ``known_trip_count`` annotation, fusions/calls by 1, conditional
branches by their max.  Shapes in the partitioned module are PER-DEVICE, so
all results here are per-device numbers.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    'pred': 1, 's8': 1, 'u8': 1, 'f8e4m3fn': 1, 'f8e5m2': 1,
    's16': 2, 'u16': 2, 'f16': 2, 'bf16': 2,
    's32': 4, 'u32': 4, 'f32': 4,
    's64': 8, 'u64': 8, 'f64': 8, 'c64': 8, 'c128': 16,
    'token': 0, 's4': 1, 'u4': 1,
}

_SHAPE_RE = re.compile(r'([a-z0-9]+)\[([\d,]*)\]')
_INSTR_RE = re.compile(r'^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$')
_COMP_RE = re.compile(r'^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$')
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_CALLS_RE = re.compile(r'(?:calls|to_apply|body)=%?([\w\.\-]+)')
_COND_RE = re.compile(r'branch_computations=\{([^}]*)\}')
_TRUE_FALSE_RE = re.compile(r'(?:true_computation|false_computation)=%?([\w\.\-]+)')

_COLLECTIVES = ('all-gather', 'all-reduce', 'reduce-scatter', 'all-to-all',
                'collective-permute')

_ZERO_COST_OPS = {
    'parameter', 'constant', 'tuple', 'get-tuple-element', 'bitcast',
    'after-all', 'reshape', 'custom-call', 'partition-id', 'replica-id',
    'get-dimension-size', 'rng-bit-generator', 'opt-barrier', 'copy-start',
    'copy-done', 'iota', 'broadcast',
}

# pure data movement: zero FLOPs, but real memory traffic
_MOVE_OPS = {
    'dynamic-slice', 'dynamic-update-slice', 'slice', 'concatenate', 'pad',
    'reverse', 'gather', 'scatter', 'copy', 'transpose', 'sort',
}


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(','):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(','):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_bytes_crosspod: float = 0.0
    coll_counts: dict = dataclasses.field(default_factory=dict)
    children: list = dataclasses.field(default_factory=list)  # (name, mult, kind)


def _dot_flops(rest: str, symbols: dict[str, str]) -> float:
    """FLOPs of a dot-general: 2 * prod(out) * prod(lhs contracting dims).

    Operands are referenced by name; shapes come from the computation's
    symbol table (instruction results + parameters).
    """
    out_elems = _shape_elems(rest)
    m = re.search(r'lhs_contracting_dims=\{([\d,]*)\}', rest)
    dims = [int(d) for d in m.group(1).split(',')] if m and m.group(1) else []
    mo = re.search(r'dot\(\s*%?([\w\.\-]+)', rest)
    contract = 1
    if mo and dims:
        lhs_type = symbols.get(mo.group(1), '')
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            shape = [int(d) for d in sm.group(2).split(',') if d]
            for d in dims:
                if d < len(shape):
                    contract *= shape[d]
    return 2.0 * out_elems * contract


def _operand_bytes(rest: str, symbols: dict[str, str], opname: str) -> int:
    """Sum the operand sizes of a dot/convolution from the symbol table."""
    m = re.search(opname + r'\(\s*%?([\w\.\-]+)\s*,\s*%?([\w\.\-]+)', rest)
    if not m:
        return 0
    return sum(_shape_bytes(symbols.get(g, '')) for g in m.groups())


def _crosses_pod(rest: str, pod_size: int) -> bool:
    m = re.search(r'replica_groups=\{?\{([^}]*)\}', rest)
    if not m:
        return False
    try:
        ids = [int(t) for t in m.group(1).replace('{', ' ').split(',')
               if t.strip().lstrip('-').isdigit()]
    except ValueError:
        return False
    if not ids:
        return False
    return any(i >= pod_size for i in ids) and any(i < pod_size for i in ids)


_PARAM_RE = re.compile(
    r'([\w\.\-]+):\s*(\((?:[^()]|\([^)]*\))*\)|[a-z0-9]+\[[\d,]*\](?:\{[^}]*\})?)')


def parse_hlo(text: str, pod_size: int = 10 ** 9) -> dict[str, CompCost]:
    """Parse module text into per-computation local costs + call edges."""
    comps: dict[str, CompCost] = {}
    cur: CompCost | None = None
    symbols: dict[str, str] = {}
    for line in text.splitlines():
        stripped = line.strip()
        if not stripped:
            continue
        cm = _COMP_RE.match(stripped)
        if cm and stripped.endswith('{'):
            cur = CompCost()
            comps[cm.group(1)] = cur
            symbols = {}
            # record parameter types from the header signature
            header = stripped[stripped.find('('):stripped.rfind('->')]
            for pname, ptype in _PARAM_RE.findall(header):
                symbols[pname] = ptype
            continue
        if stripped == '}' or cur is None:
            continue
        im = _INSTR_RE.match(line)
        if not im:
            continue
        rest = im.group(2)
        # op name = first word after the result type
        opm = re.match(r'(\((?:[^()]|\([^)]*\))*\)|\S+)\s+([\w\-]+)', rest)
        if not opm:
            continue
        symbols[im.group(1)] = opm.group(1)   # result name -> type string
        op = opm.group(2)

        if op == 'while':
            tm = _TRIP_RE.search(rest)
            mult = int(tm.group(1)) if tm else 1
            bm = re.search(r'body=%?([\w\.\-]+)', rest)
            if bm:
                cur.children.append((bm.group(1), mult, 'control'))
            cm_ = re.search(r'condition=%?([\w\.\-]+)', rest)
            if cm_:
                cur.children.append((cm_.group(1), mult + 1, 'control'))
            continue
        if op in ('fusion', 'call', 'async-start'):
            cm2 = _CALLS_RE.search(rest)
            if cm2:
                # CPU fusion regions are tiny vs TPU's; their internal costs
                # roll up like any call and their boundary bytes are NOT
                # HBM traffic on the target — see module docstring.
                cur.children.append((cm2.group(1), 1, 'control'))
            continue
        if op == 'conditional':
            branches = _COND_RE.search(rest)
            names = []
            if branches:
                names = [b.strip().lstrip('%') for b in
                         branches.group(1).split(',')]
            else:
                names = _TRUE_FALSE_RE.findall(rest)
            for nm in names:
                cur.children.append((nm, 1.0 / max(len(names), 1), 'control'))
            continue

        if any(op.startswith(c) for c in _COLLECTIVES):
            if op.endswith('-done'):   # async pair: count the start only
                continue
            nbytes = _shape_bytes(rest.split(f' {op}')[0])
            cur.coll_bytes += nbytes
            key = next(c for c in _COLLECTIVES if op.startswith(c))
            cur.coll_counts[key] = cur.coll_counts.get(key, 0) + 1
            if _crosses_pod(rest, pod_size):
                cur.coll_bytes_crosspod += nbytes
            cur.bytes += nbytes
            continue

        if op in _ZERO_COST_OPS:
            continue
        result_bytes = _shape_bytes(rest.split(f' {op}')[0])
        if op in _MOVE_OPS:
            cur.bytes += result_bytes
            continue
        if op == 'dot':
            cur.flops += _dot_flops(rest, symbols)
            cur.bytes += result_bytes + _operand_bytes(rest, symbols, 'dot')
        elif op in ('convolution',):
            # rare in this zoo; approximate as 2*out_elems (documented)
            cur.flops += 2.0 * _shape_elems(rest)
            cur.bytes += result_bytes + _operand_bytes(rest, symbols,
                                                       'convolution')
        else:
            # elementwise / reduce / compare / select ...: FLOPs count,
            # bytes assumed fused away on the TPU target
            cur.flops += _shape_elems(rest.split(f' {op}')[0])
    return comps


def aggregate(comps: dict[str, CompCost], entry: str | None = None) -> dict:
    """Roll costs up the call graph from the entry computation."""
    if entry is None:
        # ENTRY computation: the one not referenced as a child
        referenced = {name for c in comps.values() for name, _, _ in c.children}
        candidates = [n for n in comps if n not in referenced]
        entry = max(candidates, key=lambda n: comps[n].flops + comps[n].bytes,
                    default=next(iter(comps)))

    memo: dict[str, tuple] = {}

    def visit(name: str) -> tuple:
        if name in memo:
            return memo[name]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0, 0.0, {})
        memo[name] = (c.flops, c.bytes, c.coll_bytes, c.coll_bytes_crosspod,
                      dict(c.coll_counts))  # provisional (cycle guard)
        fl, by, cb, cbx = c.flops, c.bytes, c.coll_bytes, c.coll_bytes_crosspod
        cc = dict(c.coll_counts)
        for child, mult, kind in c.children:
            cf, cby, ccb, ccbx, ccc = visit(child)
            fl += mult * cf
            by += mult * cby
            cb += mult * ccb
            cbx += mult * ccbx
            for k, v in ccc.items():
                cc[k] = cc.get(k, 0) + mult * v
        memo[name] = (fl, by, cb, cbx, cc)
        return memo[name]

    fl, by, cb, cbx, cc = visit(entry)
    return {'flops': fl, 'bytes': by, 'collective_bytes': cb,
            'collective_bytes_crosspod': cbx, 'collective_counts': cc,
            'entry': entry}


def analyze_text(text: str, pod_size: int = 10 ** 9) -> dict:
    return aggregate(parse_hlo(text, pod_size))
