"""MODEL_FLOPS — the useful-work yardstick for the roofline's waste ratio.

Dense LM train step: 6*N*D (N = params participating per token, D = tokens);
MoE: 6*N_active*D.  Serve steps (prefill/decode): 2*N(_active)*D plus the
attention KV term where it matters (decode reads the whole cache per token).

These are *model* FLOPs — what a perfectly-fused implementation must spend —
compared against compiled HLO FLOPs to expose remat/redundancy waste.
"""
from __future__ import annotations

import math
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig
from repro.runtime.sharding import padded_heads


def param_count(cfg: ModelConfig, *, active_only: bool = False,
                tp: int = 1) -> int:
    """Parameters in one forward pass (active_only: MoE top-k experts only).

    Counts the *unpadded* logical model (padding is waste, not useful work).
    """
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd = cfg.resolved_head_dim()
    hq = cfg.n_heads * hd
    hkv = cfg.n_kv_heads * hd

    def attn():
        return d * hq + 2 * d * hkv + hq * d

    def dense_mlp(ff=None):
        ff = ff or f
        n_mats = 3 if cfg.act == 'swiglu' else 2
        return n_mats * d * ff

    total = v * d  # embedding
    if not cfg.tie_embeddings:
        total += d * v

    if cfg.family in ('dense', 'vlm'):
        total += cfg.n_layers * (attn() + dense_mlp())
    elif cfg.family == 'encdec':
        enc = cfg.enc_layers or cfg.n_layers
        total += enc * (attn() + dense_mlp())
        total += cfg.n_layers * (2 * attn() + dense_mlp())  # self + cross
    elif cfg.family == 'moe':
        n_moe = cfg.n_layers // cfg.moe_every
        n_dense = cfg.n_layers - n_moe
        total += cfg.n_layers * attn()
        total += n_dense * dense_mlp()
        experts = cfg.top_k if active_only else cfg.n_experts
        n_mats = 3 if cfg.act == 'swiglu' else 2
        total += n_moe * (experts * n_mats * d * f + d * cfg.n_experts)
        if cfg.shared_expert:
            total += n_moe * dense_mlp()
    elif cfg.family == 'ssm':
        di = 2 * d
        per_m = d * di * 2 + 3 * di * di + di * 2 * cfg.n_heads + di * d
        per_s = d * 4 * di + di * 4 * di + di * d
        se = cfg.slstm_every or (cfg.n_layers + 1)
        n_s = cfg.n_layers // se if cfg.n_layers % se == 0 else 0
        total += (cfg.n_layers - n_s) * per_m + n_s * per_s
    elif cfg.family == 'hybrid':
        di = 2 * d
        ds = cfg.ssm_state
        h = di // cfg.ssm_head_dim
        per_mamba = d * (2 * di + 2 * ds + h) + di * d
        total += cfg.n_layers * per_mamba
        ae = cfg.attn_every or (cfg.n_layers + 1)
        if len([l for l in range(cfg.n_layers) if (l + 1) % ae == 0]):
            total += attn() + dense_mlp()  # ONE shared block
    else:
        raise ValueError(cfg.family)
    return total


def _attn_flops_per_layer(cfg: ModelConfig, seq: int, batch: int,
                          causal: bool = True) -> float:
    """Score+AV FLOPs of full attention (not counted in 6ND)."""
    hd = cfg.resolved_head_dim()
    h = cfg.n_heads
    pairs = seq * seq * (0.5 if causal else 1.0)
    return batch * h * pairs * hd * 2 * 2  # QK^T + PV, 2 flops/MAC


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS for one step of (cfg x shape)."""
    b, s = shape.global_batch, shape.seq_len
    n_act = param_count(cfg, active_only=True)

    if shape.kind == 'train':
        # fwd 2ND + bwd 4ND, plus attention quadratic term (x3 for bwd)
        flops = 6.0 * n_act * b * s
        if cfg.family in ('dense', 'vlm', 'moe'):
            flops += 3.0 * cfg.n_layers * _attn_flops_per_layer(cfg, s, b)
        elif cfg.family == 'encdec':
            enc = cfg.enc_layers or cfg.n_layers
            flops += 3.0 * enc * _attn_flops_per_layer(cfg, s, b, causal=False)
            flops += 3.0 * cfg.n_layers * (
                _attn_flops_per_layer(cfg, s, b)
                + _attn_flops_per_layer(cfg, s, b, causal=False))
        elif cfg.family == 'hybrid':
            ae = cfg.attn_every or (cfg.n_layers + 1)
            n_pts = len([l for l in range(cfg.n_layers) if (l + 1) % ae == 0])
            flops += 3.0 * n_pts * _attn_flops_per_layer(cfg, s, b)
        return flops

    if shape.kind == 'prefill':
        flops = 2.0 * n_act * b * s
        if cfg.family in ('dense', 'vlm', 'moe'):
            flops += cfg.n_layers * _attn_flops_per_layer(cfg, s, b)
        elif cfg.family == 'encdec':
            enc = cfg.enc_layers or cfg.n_layers
            flops += enc * _attn_flops_per_layer(cfg, s, b, causal=False)
            flops += cfg.n_layers * (_attn_flops_per_layer(cfg, s, b)
                                     + _attn_flops_per_layer(cfg, s, b,
                                                             causal=False))
        elif cfg.family == 'hybrid':
            ae = cfg.attn_every or (cfg.n_layers + 1)
            n_pts = len([l for l in range(cfg.n_layers) if (l + 1) % ae == 0])
            flops += n_pts * _attn_flops_per_layer(cfg, s, b)
        return flops

    # decode: one token; params read once, KV cache read once per attn layer
    flops = 2.0 * n_act * b
    hd = cfg.resolved_head_dim()
    kv_layers = 0
    if cfg.family in ('dense', 'vlm', 'moe'):
        kv_layers = cfg.n_layers
    elif cfg.family == 'encdec':
        kv_layers = 2 * cfg.n_layers
    elif cfg.family == 'hybrid':
        ae = cfg.attn_every or (cfg.n_layers + 1)
        kv_layers = len([l for l in range(cfg.n_layers) if (l + 1) % ae == 0])
    flops += kv_layers * b * cfg.n_heads * s * hd * 2 * 2
    return flops


def hbm_bytes_decode(cfg: ModelConfig, shape: ShapeConfig,
                     dtype_bytes: int = 2) -> float:
    """Minimum HBM traffic of a decode step: params once + KV cache once."""
    n = param_count(cfg, active_only=True)
    hd = cfg.resolved_head_dim()
    b, s = shape.global_batch, shape.seq_len
    kv_layers = cfg.n_layers if cfg.family in ('dense', 'vlm', 'moe') else 0
    if cfg.family == 'encdec':
        kv_layers = 2 * cfg.n_layers
    if cfg.family == 'hybrid':
        ae = cfg.attn_every or (cfg.n_layers + 1)
        kv_layers = len([l for l in range(cfg.n_layers) if (l + 1) % ae == 0])
    kv = kv_layers * b * s * cfg.n_kv_heads * hd * 2  # k and v
    return (n + kv) * dtype_bytes
