"""Three-term roofline from a compiled dry-run artifact (§Roofline).

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

Hardware constants: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM,
~50 GB/s/link ICI (per the assignment).

Sources: per-device FLOPs/bytes/collective-bytes come from the optimized-HLO
parser (``repro.analysis.hlo_parse``) which applies while-loop trip counts
up the call graph — ``compiled.cost_analysis()`` under-counts scanned layers
(it visits each computation once), so we parse the module text instead and
cross-check against cost_analysis in tests.  Shapes in the partitioned
module are per-device, so parsed numbers are already per-chip.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from repro.analysis import hlo_parse

PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
ICI_BW = 50e9           # bytes/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device (per-chip) raw terms
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_bytes_crosspod_per_chip: float
    collective_counts: dict
    # seconds
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    # analysis
    bottleneck: str = ''
    model_flops: float = 0.0
    useful_ratio: float = 0.0      # MODEL_FLOPS / (HLO_FLOPs * chips)
    bytes_per_device_hbm: float = 0.0   # peak allocation from memory_analysis
    note: str = ''

    def finalize(self) -> 'Roofline':
        self.t_compute = self.flops_per_chip / PEAK_FLOPS
        self.t_memory = self.bytes_per_chip / HBM_BW
        self.t_collective = self.coll_bytes_per_chip / ICI_BW
        terms = {'compute': self.t_compute, 'memory': self.t_memory,
                 'collective': self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        total_hlo = self.flops_per_chip * self.chips
        self.useful_ratio = (self.model_flops / total_hlo) if total_hlo else 0.0
        return self

    @property
    def step_time(self) -> float:
        """Roofline-optimistic step time: max of the three terms (perfect
        overlap of compute, HBM, and ICI)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step spent on the useful-compute floor: how close
        the compiled program is to a perfect 6ND implementation at peak."""
        ideal = self.model_flops / (self.chips * PEAK_FLOPS)
        return ideal / self.step_time if self.step_time else 0.0

    def row(self) -> dict:
        return {
            'arch': self.arch, 'shape': self.shape, 'mesh': self.mesh,
            'chips': self.chips,
            't_compute_s': self.t_compute, 't_memory_s': self.t_memory,
            't_collective_s': self.t_collective,
            'bottleneck': self.bottleneck,
            'model_flops': self.model_flops,
            'hlo_flops_total': self.flops_per_chip * self.chips,
            'useful_ratio': self.useful_ratio,
            'roofline_fraction': self.roofline_fraction,
            'hbm_bytes_per_device': self.bytes_per_device_hbm,
            'collective_counts': self.collective_counts,
            'coll_bytes_crosspod_per_chip': self.coll_bytes_crosspod_per_chip,
            'note': self.note,
        }


def from_compiled(arch: str, shape: str, mesh_name: str, chips: int,
                  hlo_text: str, *, model_flops: float = 0.0,
                  pod_size: int = 256, memory_analysis=None,
                  note: str = '') -> Roofline:
    """Build a Roofline from compiled HLO text (+ optional memory_analysis)."""
    agg = hlo_parse.analyze_text(hlo_text, pod_size=pod_size)
    peak = 0.0
    if memory_analysis is not None:
        # works for both the CPU and TPU MemoryAnalysis protos
        for attr in ('temp_size_in_bytes', 'argument_size_in_bytes',
                     'output_size_in_bytes'):
            peak += float(getattr(memory_analysis, attr, 0) or 0)
        gen = float(getattr(memory_analysis, 'generated_code_size_in_bytes', 0)
                    or 0)
        peak += gen
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=agg['flops'],
        bytes_per_chip=agg['bytes'],
        coll_bytes_per_chip=agg['collective_bytes'],
        coll_bytes_crosspod_per_chip=agg['collective_bytes_crosspod'],
        collective_counts=agg['collective_counts'],
        model_flops=model_flops,
        bytes_per_device_hbm=peak,
        note=note,
    ).finalize()


def fmt_seconds(x: float) -> str:
    if x >= 1.0:
        return f'{x:.2f}s'
    if x >= 1e-3:
        return f'{x * 1e3:.2f}ms'
    return f'{x * 1e6:.1f}us'


def fmt_table(rows: list[dict]) -> str:
    hdr = (f"{'arch':<26} {'shape':<12} {'mesh':<6} "
           f"{'compute':>9} {'memory':>9} {'collect':>9} {'bound':>9} "
           f"{'useful':>7} {'roofl%':>7}")
    out = [hdr, '-' * len(hdr)]
    for r in rows:
        out.append(
            f"{r['arch']:<26} {r['shape']:<12} {r['mesh']:<6} "
            f"{fmt_seconds(r['t_compute_s']):>9} "
            f"{fmt_seconds(r['t_memory_s']):>9} "
            f"{fmt_seconds(r['t_collective_s']):>9} "
            f"{r['bottleneck']:>9} "
            f"{r['useful_ratio']:>7.2f} "
            f"{100 * r['roofline_fraction']:>6.1f}%")
    return '\n'.join(out)


def save_rows(rows: list[dict], path: str) -> None:
    with open(path, 'w') as f:
        json.dump(rows, f, indent=1, default=str)


def load_rows(path: str) -> list[dict]:
    with open(path) as f:
        return json.load(f)
