"""Typed metrics for the serving stack: counters, gauges, histograms and
per-tick series behind one registry.

The serving layers publish **named, typed** instruments here instead of
growing ad-hoc dict plumbing:

  * ``Counter``   — monotonic totals (``sort.executed`` per (scene, pose
    cell), ``serve.admitted`` / ``serve.evicted``, ``serve.paced_idle``);
  * ``Gauge``     — last-value samples (``serve.queue_depth``,
    ``cache.occupancy``, state-byte figures);
  * ``Histogram`` — raw-sample distributions with exact percentiles
    (``serve.tick_latency_ms``, per-scene ``cache.hit_rate``,
    ``rc.saved_frac`` — the trim/compaction saving);
  * ``Series``    — per-tick time series keyed by the virtual tick clock.
    ``SessionManager.observe_tick`` publishes every tick-log field here via
    :func:`publish_tick`, and :func:`tick_rollup_from_metrics` recomputes
    ``repro.serve.telemetry.tick_rollup`` **bit-compatibly** from the
    registry (pinned by ``tests/test_obs.py``) — the registry is the
    superset the legacy dict rollup is now a view of.

Naming convention: dot-separated ``subsystem.metric`` (``serve.*`` manager
/ admission, ``sort.*`` pose-cell scheduler, ``cache.*`` radiance cache,
``rc.*`` redundancy accounting, ``tick.*`` reserved for the per-tick
series), with low-cardinality labels (``scene=``, ``cell=``) carried on the
instrument key, Prometheus-style: ``sort.executed{cell=17,scene=0}``.

A name is permanently typed: re-registering ``serve.frames`` as a gauge
after it existed as a counter raises — silent type drift is how rollups
rot.  All mutation goes through the registry lock, so the threaded
driver's planner worker may publish concurrently with the main loop.
"""
from __future__ import annotations

import json
import threading

import numpy as np


class Counter:
    """Monotonic accumulator."""

    kind = 'counter'
    __slots__ = ('name', 'description', 'unit', 'value')

    def __init__(self, name: str, description: str = '', unit: str = ''):
        self.name = name
        self.description = description
        self.unit = unit
        self.value = 0

    def inc(self, v=1) -> None:
        if v < 0:
            raise ValueError(f'counter {self.name} cannot decrease (inc {v})')
        self.value += v

    def snapshot(self) -> dict:
        return {'type': self.kind, 'value': self.value}


class Gauge:
    """Last-value sample (plus the observed min/max envelope)."""

    kind = 'gauge'
    __slots__ = ('name', 'description', 'unit', 'value', 'min', 'max')

    def __init__(self, name: str, description: str = '', unit: str = ''):
        self.name = name
        self.description = description
        self.unit = unit
        self.value = None
        self.min = None
        self.max = None

    def set(self, v) -> None:
        self.value = v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def snapshot(self) -> dict:
        return {'type': self.kind, 'value': self.value,
                'min': self.min, 'max': self.max}


class Histogram:
    """Raw-sample distribution: exact count/sum/percentiles.

    Samples are kept verbatim (serving runs are thousands of ticks, not
    millions of requests); ``percentile`` matches ``np.percentile`` so the
    numbers line up with ``tick_rollup``'s p50/p95.
    """

    kind = 'histogram'
    __slots__ = ('name', 'description', 'unit', 'samples')

    def __init__(self, name: str, description: str = '', unit: str = ''):
        self.name = name
        self.description = description
        self.unit = unit
        self.samples: list = []

    def observe(self, v) -> None:
        self.samples.append(v)

    @property
    def count(self) -> int:
        return len(self.samples)

    @property
    def sum(self):
        return sum(self.samples)

    def percentile(self, p: float) -> float:
        if not self.samples:
            return 0.0
        return float(np.percentile(np.asarray(self.samples, np.float64), p))

    def snapshot(self) -> dict:
        return {'type': self.kind, 'count': self.count,
                'sum': float(self.sum) if self.samples else 0.0,
                'p50': self.percentile(50), 'p95': self.percentile(95),
                'p99': self.percentile(99)}


class Series:
    """Per-tick samples ``(tick, value)`` on the virtual tick clock."""

    kind = 'series'
    __slots__ = ('name', 'description', 'unit', 'samples')

    def __init__(self, name: str, description: str = '', unit: str = ''):
        self.name = name
        self.description = description
        self.unit = unit
        self.samples: list = []

    def record(self, tick: int, value) -> None:
        self.samples.append((tick, value))

    def snapshot(self) -> dict:
        return {'type': self.kind, 'ticks': len(self.samples),
                'last': self.samples[-1][1] if self.samples else None}


class Registry:
    """Get-or-create instrument registry, keyed by (name, labels)."""

    def __init__(self):
        self._metrics: dict = {}
        self._lock = threading.Lock()

    @staticmethod
    def _key(name: str, labels: dict) -> str:
        if not labels:
            return name
        inner = ','.join(f'{k}={labels[k]}' for k in sorted(labels))
        return f'{name}{{{inner}}}'

    def _get(self, cls, name: str, description: str, unit: str,
             labels: dict):
        key = self._key(name, labels)
        with self._lock:
            inst = self._metrics.get(key)
            if inst is None:
                inst = self._metrics[key] = cls(key, description, unit)
            elif not isinstance(inst, cls):
                raise TypeError(f'metric {key!r} already registered as '
                                f'{inst.kind}, requested {cls.kind}')
            return inst

    def counter(self, name: str, description: str = '', unit: str = '',
                **labels) -> Counter:
        return self._get(Counter, name, description, unit, labels)

    def gauge(self, name: str, description: str = '', unit: str = '',
              **labels) -> Gauge:
        return self._get(Gauge, name, description, unit, labels)

    def histogram(self, name: str, description: str = '', unit: str = '',
                  **labels) -> Histogram:
        return self._get(Histogram, name, description, unit, labels)

    def series(self, name: str, description: str = '', unit: str = '',
               **labels) -> Series:
        return self._get(Series, name, description, unit, labels)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return key in self._metrics

    def __getitem__(self, key: str):
        with self._lock:
            return self._metrics[key]

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def snapshot(self) -> dict:
        """JSON-serializable view of every instrument (``--metrics-out``)."""
        with self._lock:
            items = list(self._metrics.items())
        return {key: _jsonable(inst.snapshot()) for key, inst in items}

    def to_json(self, **kwargs) -> str:
        return json.dumps(self.snapshot(), **kwargs)


def _jsonable(obj):
    """Coerce numpy / jax scalars (telemetry defers device syncs) so the
    snapshot dumps cleanly."""
    if isinstance(obj, dict):
        return {k: _jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_jsonable(v) for v in obj]
    if isinstance(obj, (int, float, str, bool)) or obj is None:
        return obj
    if hasattr(obj, 'item'):
        return obj.item()
    return float(obj)


# -- the tick-series mirror of SessionManager.tick_log ----------------------

TICK_PREFIX = 'tick.'
_KERNEL_PREFIX = TICK_PREFIX + 'kernel_ms.'


def publish_tick(registry: Registry, entry: dict) -> None:
    """Mirror one ``SessionManager.tick_log`` entry into per-tick series.

    Scalar fields land verbatim on ``tick.<field>`` (values are stored
    as-is — possibly still-unsynced device scalars, exactly like the dict
    path; ``tick_rollup`` is where they become floats), the nested
    ``kernel_ms`` breakdown on ``tick.kernel_ms.<stage>``.  A ``None``
    ``kernel_ms`` (unprofiled tick) records nothing, matching the dict
    path's falsy-skip.
    """
    tick = entry['tick']
    for key, value in entry.items():
        if key == 'tick':
            continue
        if key == 'kernel_ms':
            if value:
                for stage, ms in value.items():
                    registry.series(_KERNEL_PREFIX + stage).record(tick, ms)
            continue
        registry.series(TICK_PREFIX + key).record(tick, value)


def tick_log_from_registry(registry: Registry) -> list:
    """Reconstruct the tick log from the registry's ``tick.*`` series —
    the inverse of :func:`publish_tick`, up to dict key order."""
    fields: dict[str, dict] = {}
    kernel: dict[str, dict] = {}
    for key in registry.names():
        if key.startswith(_KERNEL_PREFIX):
            kernel[key[len(_KERNEL_PREFIX):]] = dict(registry[key].samples)
        elif key.startswith(TICK_PREFIX):
            fields[key[len(TICK_PREFIX):]] = dict(registry[key].samples)
    ticks = sorted({t for by_tick in fields.values() for t in by_tick})
    log = []
    for t in ticks:
        entry = {'tick': t}
        for field, by_tick in fields.items():
            if t in by_tick:
                entry[field] = by_tick[t]
        kms = {stage: by_tick[t] for stage, by_tick in kernel.items()
               if t in by_tick}
        entry['kernel_ms'] = kms or None
        log.append(entry)
    return log


def tick_rollup_from_metrics(registry: Registry,
                             warmup_ticks: int = 0) -> dict:
    """``repro.serve.telemetry.tick_rollup`` recomputed from the registry's
    tick series.  Bit-identical to the dict path on the same run: the
    series hold the tick-log values verbatim and the rollup arithmetic is
    literally shared."""
    from repro.serve.telemetry import tick_rollup   # avoid an import cycle
    return tick_rollup(tick_log_from_registry(registry),
                       warmup_ticks=warmup_ticks)
