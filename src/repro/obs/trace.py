"""Low-overhead span/instant tracing for the serving host pipeline.

A :class:`Tracer` records three event shapes onto named **tracks** (host,
host-worker, device — each exported as its own Perfetto/`chrome://tracing`
thread lane, see ``repro.obs.export``):

  * **context-manager spans** — ``with tracer.span('plan_tick', tick=t):``
    times host-side work on the calling thread's track.  Nesting depth is
    maintained per (thread, track) so the exported trace shows the real
    call structure (``tick`` > ``plan_tick`` / ``apply_plan`` /
    ``observe_tick``);
  * **explicit complete spans** — ``tracer.complete(name, t0, t1,
    track='device')`` for intervals whose begin/end straddle calls, e.g.
    the device window of an async shade (``step_dispatch`` records the
    dispatch time, ``step_finish`` closes the span once
    ``block_until_ready`` returns) and the sampled kernel-stage breakdown;
  * **instants** — ``tracer.instant('admit', slot=3, sid=7)`` for traffic
    events (arrival / admit / evict / pace) that have no duration.

Determinism contract: under the virtual-clock ``SyncDriver`` the serving
control flow is a pure function of the submitted trace, so the *structure*
of the recorded spans — per-track (name, depth, args) sequences, exposed by
:func:`span_structure` — is bit-identical across replays.  Timestamps are
wall-clock and of course differ; they never enter the structure.

Overhead: the module-level :data:`NULL` tracer is the default everywhere —
its ``span`` returns one shared no-op context manager and ``complete`` /
``instant`` are empty methods, so uninstrumented serving pays a single
attribute lookup per site.  A live tracer appends one small tuple per
event under a lock (the threaded driver's planner worker and the main
thread both record).
"""
from __future__ import annotations

import threading
import time
from typing import Callable, NamedTuple, Optional

# Event phases, mirroring the Chrome trace-event vocabulary the exporter
# targets: 'X' = complete span (ts + dur), 'i' = instant.
PH_SPAN = 'X'
PH_INSTANT = 'i'

# Canonical track names.  Spans recorded without an explicit track land on
# the calling thread's default: the main thread is the serving loop
# ('host'); any other thread is host planning work ('host-worker' — the
# ThreadedDriver's planner).  Device windows are always explicit.
TRACK_HOST = 'host'
TRACK_WORKER = 'host-worker'
TRACK_DEVICE = 'device'


class TraceEvent(NamedTuple):
    """One recorded event.  ``ts``/``dur`` are seconds on the tracer's
    clock (perf_counter by default); ``depth`` is the span-nesting level
    within its track (0 = top level); ``args`` is a tuple of sorted
    (key, value) pairs — deterministic under replay by construction, the
    callers only attach control-flow values (tick numbers, slots, counts),
    never wall-clock readings."""

    ph: str
    name: str
    track: str
    ts: float
    dur: float
    depth: int
    args: tuple


class _Span:
    """Reusable enter/exit handle for one context-manager span."""

    __slots__ = ('_tracer', '_name', '_track', '_args', '_t0')

    def __init__(self, tracer: 'Tracer', name: str, track: str, args: tuple):
        self._tracer = tracer
        self._name = name
        self._track = track
        self._args = args

    def __enter__(self):
        tr = self._tracer
        tr._push(self._track)
        self._t0 = tr._clock()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        t1 = tr._clock()
        depth = tr._pop(self._track)
        tr._record(TraceEvent(PH_SPAN, self._name, self._track,
                              self._t0, t1 - self._t0, depth, self._args))
        return False


class Tracer:
    """Collects :class:`TraceEvent` records; thread-safe."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._events: list[TraceEvent] = []
        self._lock = threading.Lock()
        self._local = threading.local()

    # -- per-(thread, track) nesting depth ---------------------------------

    def _depths(self) -> dict:
        d = getattr(self._local, 'depths', None)
        if d is None:
            d = self._local.depths = {}
        return d

    def _push(self, track: str) -> None:
        d = self._depths()
        d[track] = d.get(track, 0) + 1

    def _pop(self, track: str) -> int:
        d = self._depths()
        d[track] -= 1
        return d[track]

    def _default_track(self) -> str:
        if threading.current_thread() is threading.main_thread():
            return TRACK_HOST
        return TRACK_WORKER

    def _record(self, ev: TraceEvent) -> None:
        with self._lock:
            self._events.append(ev)

    # -- recording API ------------------------------------------------------

    def span(self, name: str, track: Optional[str] = None, **args) -> _Span:
        """Context manager timing a host-side span on ``track`` (default:
        the calling thread's track)."""
        return _Span(self, name, track or self._default_track(),
                     tuple(sorted(args.items())))

    def complete(self, name: str, t0: float, t1: float,
                 track: str = TRACK_DEVICE, depth: int = 0, **args) -> None:
        """Record a span whose begin/end were measured explicitly (seconds
        on this tracer's clock) — device windows, sampled kernel stages."""
        self._record(TraceEvent(PH_SPAN, name, track, t0, max(0.0, t1 - t0),
                                depth, tuple(sorted(args.items()))))

    def instant(self, name: str, track: Optional[str] = None, **args) -> None:
        self._record(TraceEvent(PH_INSTANT, name,
                                track or self._default_track(),
                                self._clock(), 0.0, 0,
                                tuple(sorted(args.items()))))

    # -- reading ------------------------------------------------------------

    @property
    def events(self) -> list[TraceEvent]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


class _NullTracer:
    """No-op tracer: the default when observability is off."""

    enabled = False
    events: list = []
    _null_span = _NullSpan()

    def span(self, name, track=None, **args):
        return self._null_span

    def complete(self, name, t0, t1, track=TRACK_DEVICE, depth=0, **args):
        pass

    def instant(self, name, track=None, **args):
        pass

    def clear(self):
        pass


NULL = _NullTracer()


def span_structure(events) -> dict:
    """The wall-clock-free shape of a trace: per-track tuples of
    ``(ph, name, depth, args)`` in record order.  Two SyncDriver replays of
    the same traffic trace must produce equal structures — the determinism
    oracle ``tests/test_obs.py`` pins."""
    out: dict[str, list] = {}
    for ev in events:
        out.setdefault(ev.track, []).append(
            (ev.ph, ev.name, ev.depth, ev.args))
    return {track: tuple(seq) for track, seq in out.items()}
