"""Observability for the serving stack: span tracing, typed metrics,
Perfetto export.

  * ``trace``   — low-overhead span/instant tracer with host / host-worker
    / device tracks (``NULL`` no-op tracer by default);
  * ``export``  — Chrome trace-event JSON (Perfetto / ``chrome://tracing``)
    serialization + schema validation;
  * ``metrics`` — typed counter/gauge/histogram/series registry the
    steppers and ``SessionManager`` publish into; ``tick_rollup`` is
    recomputable from it bit-compatibly.

This package deliberately imports nothing from ``repro.serve`` at module
scope (the serving layers import *it*); the one telemetry reuse in
``metrics.tick_rollup_from_metrics`` is deferred.
"""
from repro.obs.export import (to_chrome_trace, track_spans,
                              validate_chrome_trace, write_trace)
from repro.obs.metrics import (Counter, Gauge, Histogram, Registry, Series,
                               publish_tick, tick_log_from_registry,
                               tick_rollup_from_metrics)
from repro.obs.trace import (NULL, TRACK_DEVICE, TRACK_HOST, TRACK_WORKER,
                             TraceEvent, Tracer, span_structure)

__all__ = [
    'Tracer', 'TraceEvent', 'NULL', 'span_structure',
    'TRACK_HOST', 'TRACK_WORKER', 'TRACK_DEVICE',
    'to_chrome_trace', 'write_trace', 'validate_chrome_trace', 'track_spans',
    'Counter', 'Gauge', 'Histogram', 'Series', 'Registry',
    'publish_tick', 'tick_log_from_registry', 'tick_rollup_from_metrics',
]
