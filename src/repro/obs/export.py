"""Chrome trace-event export: load the serving trace in Perfetto.

Converts a :class:`repro.obs.trace.Tracer`'s events into the Chrome
trace-event JSON object format — ``{"traceEvents": [...],
"displayTimeUnit": "ms"}`` — loadable in https://ui.perfetto.dev or
``chrome://tracing``.  Each tracer track becomes its own thread lane
(``tid``) under one process, named via ``M``-phase metadata events, so the
threaded driver's plan(t+1) ∥ device(t) overlap is visible as a
``host-worker`` span sitting under an open ``device`` span instead of a
single ``host_overlap`` scalar.

Timestamps are exported in microseconds relative to the earliest event
(Chrome's unit), durations likewise; span nesting follows from timestamp
containment per lane, which matches the tracer's per-thread LIFO span
stack by construction.
"""
from __future__ import annotations

import json
from typing import Iterable

from repro.obs.trace import (PH_INSTANT, PH_SPAN, TRACK_DEVICE, TRACK_HOST,
                             TRACK_WORKER, TraceEvent, Tracer)

PID = 1
PROCESS_NAME = 'repro.serve'
# Stable lane ordering for the canonical tracks; unknown tracks follow.
_TRACK_ORDER = {TRACK_HOST: 1, TRACK_WORKER: 2, TRACK_DEVICE: 3}


def _track_tids(events: Iterable[TraceEvent]) -> dict:
    tracks = sorted({ev.track for ev in events},
                    key=lambda t: (_TRACK_ORDER.get(t, 99), t))
    return {track: _TRACK_ORDER.get(track, 10 + i)
            for i, track in enumerate(tracks)}


def to_chrome_trace(events: Iterable[TraceEvent],
                    process_name: str = PROCESS_NAME) -> dict:
    """Build the Chrome trace-event JSON object for ``events``."""
    events = list(events)
    tids = _track_tids(events)
    t_base = min((ev.ts for ev in events), default=0.0)
    out = [{'ph': 'M', 'name': 'process_name', 'pid': PID, 'tid': 0,
            'args': {'name': process_name}}]
    for track, tid in tids.items():
        out.append({'ph': 'M', 'name': 'thread_name', 'pid': PID,
                    'tid': tid, 'args': {'name': track}})
        out.append({'ph': 'M', 'name': 'thread_sort_index', 'pid': PID,
                    'tid': tid, 'args': {'sort_index': tid}})
    for ev in events:
        rec = {
            'ph': ev.ph,
            'name': ev.name,
            'cat': ev.track,
            'ts': (ev.ts - t_base) * 1e6,
            'pid': PID,
            'tid': tids[ev.track],
            'args': dict(ev.args),
        }
        if ev.ph == PH_SPAN:
            rec['dur'] = ev.dur * 1e6
        elif ev.ph == PH_INSTANT:
            rec['s'] = 't'   # thread-scoped instant
        out.append(rec)
    return {'traceEvents': out, 'displayTimeUnit': 'ms'}


def write_trace(path: str, tracer_or_events,
                process_name: str = PROCESS_NAME) -> dict:
    """Write a tracer's events as Chrome trace JSON; returns the payload."""
    events = (tracer_or_events.events
              if isinstance(tracer_or_events, Tracer) else tracer_or_events)
    payload = to_chrome_trace(events, process_name=process_name)
    with open(path, 'w') as f:
        json.dump(payload, f)
    return payload


def validate_chrome_trace(payload: dict) -> list:
    """Schema-check a Chrome trace-event JSON object; returns the event
    list.  Raises ``ValueError`` naming the first malformed record — the
    cheap loadability oracle tests and the CLI share (Perfetto itself is
    the authority, but it is not in the container)."""
    if not isinstance(payload, dict) or 'traceEvents' not in payload:
        raise ValueError('trace must be a JSON object with "traceEvents"')
    events = payload['traceEvents']
    if not isinstance(events, list):
        raise ValueError('"traceEvents" must be a list')
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f'traceEvents[{i}] is not an object')
        for field in ('ph', 'name', 'pid', 'tid'):
            if field not in ev:
                raise ValueError(f'traceEvents[{i}] missing {field!r}')
        if ev['ph'] == PH_SPAN:
            for field in ('ts', 'dur'):
                if not isinstance(ev.get(field), (int, float)) \
                        or ev[field] < 0:
                    raise ValueError(
                        f'traceEvents[{i}] ({ev["name"]}): bad {field!r}')
        elif ev['ph'] == PH_INSTANT:
            if not isinstance(ev.get('ts'), (int, float)):
                raise ValueError(
                    f'traceEvents[{i}] ({ev["name"]}): bad "ts"')
        elif ev['ph'] != 'M':
            raise ValueError(f'traceEvents[{i}]: unknown phase {ev["ph"]!r}')
    return events


def track_spans(payload: dict, track: str) -> list:
    """The ``(ts, ts + dur, name, args)`` complete spans of one named track
    of an exported trace, in timestamp order — the helper overlap checks
    are written against."""
    events = validate_chrome_trace(payload)
    tid = next((ev['tid'] for ev in events
                if ev['ph'] == 'M' and ev['name'] == 'thread_name'
                and ev['args'].get('name') == track), None)
    if tid is None:
        return []
    spans = [(ev['ts'], ev['ts'] + ev['dur'], ev['name'], ev.get('args', {}))
             for ev in events if ev['ph'] == PH_SPAN and ev['tid'] == tid]
    return sorted(spans, key=lambda s: s[0])
