"""Fault-tolerant checkpointing: atomic, async, sharded, keep-K, auto-resume.

Design (np-backed, no external deps — works on any fs the hosts share):

  * **Sharded**: each host writes only the shards it owns (``npz`` per host,
    ``host<i>.npz``), so checkpoint bandwidth scales with the host count and
    no host ever materializes the global state.  On a single-host run (tests,
    CPU container) there is exactly one shard file.
  * **Atomic**: writes land in ``step_<n>.tmp/`` and the directory is
    ``rename()``d to ``step_<n>/`` only after every shard + the manifest are
    fsync'd.  A crash mid-write can never corrupt the latest checkpoint —
    ``latest()`` only ever sees completed renames.
  * **Async**: ``save()`` snapshots device arrays to host memory
    (``jax.device_get`` — the only synchronous part) and hands serialization
    to a background thread, so the train loop resumes immediately
    (double-buffered: at most one in-flight save; a second save waits).
  * **Keep-K**: older checkpoints are garbage-collected after a successful
    save; ``keep_every`` marks permanent archival checkpoints.
  * **Auto-resume**: ``restore_latest()`` scans the directory, picks the
    newest complete checkpoint and reassembles the pytree (re-sharding to
    the current mesh is the caller's job via ``jax.device_put``; see
    repro.runtime.elastic for mesh-size changes).

The manifest stores the pytree structure (treedef repr + leaf paths) and a
payload checksum so silent corruption is detected at restore.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
import warnings
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_names(tree: Any):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names, leaves = [], []
    for path, leaf in leaves_with_paths:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves


def _checksum(arrays: dict) -> str:
    h = hashlib.sha256()
    for name in sorted(arrays):
        a = np.ascontiguousarray(arrays[name])
        h.update(name.encode())
        h.update(str(a.dtype).encode())
        h.update(str(a.shape).encode())
        h.update(a.tobytes()[:1 << 20])   # first MB per array: cheap + catches truncation
    return h.hexdigest()


def save_checkpoint(path: str | Path, tree: Any, *, step: int,
                    host_id: int = 0, num_hosts: int = 1,
                    extra: Optional[dict] = None) -> Path:
    """Synchronous sharded save of ``tree`` under ``path/step_<step>``."""
    path = Path(path)
    final = path / f'step_{step:010d}'
    tmp = path / f'step_{step:010d}.tmp'
    tmp.mkdir(parents=True, exist_ok=True)

    names, leaves = _flatten_with_names(tree)
    host_arrays = {}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        if i % num_hosts != host_id:
            continue   # each host persists only the shards it owns
        host_arrays[name] = np.asarray(jax.device_get(leaf))

    shard_file = tmp / f'host{host_id}.npz'
    with open(shard_file, 'wb') as f:
        np.savez(f, **{_safe(n): a for n, a in host_arrays.items()})
        f.flush()
        os.fsync(f.fileno())

    manifest = {
        'step': step,
        'num_hosts': num_hosts,
        'names': names,
        'host_of': {n: (i % num_hosts) for i, n in enumerate(names)},
        'checksum': {f'host{host_id}': _checksum(host_arrays)},
        'time': time.time(),
        'extra': extra or {},
    }
    # host 0 owns the manifest; other hosts write side manifests merged later
    mf = tmp / ('manifest.json' if host_id == 0 else f'manifest.host{host_id}.json')
    with open(mf, 'w') as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())

    if host_id == 0:
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)    # atomic publish
    return final


def _safe(name: str) -> str:
    return name.replace('/', '__')


def load_checkpoint(path: str | Path, tree_like: Any, *, step: int) -> tuple:
    """Load ``step`` into the structure of ``tree_like``. Returns (tree, extra)."""
    path = Path(path) / f'step_{step:010d}'
    with open(path / 'manifest.json') as f:
        manifest = json.load(f)
    names, leaves = _flatten_with_names(tree_like)
    if names != manifest['names']:
        raise ValueError('checkpoint pytree structure mismatch: '
                         f'{len(names)} leaves now vs {len(manifest["names"])} saved')
    unsafe = {_safe(n): n for n in manifest['names']}
    arrays: dict = {}
    for hf in sorted(path.glob('host*.npz')):
        host_arrays: dict = {}
        with np.load(hf) as z:
            for k in z.files:
                # npz keys are filesystem-safe names; checksums were taken
                # over the original leaf names at save time
                host_arrays[unsafe.get(k, k)] = z[k]
        want = manifest.get('checksum', {}).get(hf.stem)
        if want is not None and _checksum(host_arrays) != want:
            raise ValueError(f'checksum mismatch in {hf.name}: '
                             'shard bytes corrupted since save')
        arrays.update({_safe(n): a for n, a in host_arrays.items()})
    out = []
    for name, leaf in zip(names, leaves):
        a = arrays.get(_safe(name))
        if a is None:
            raise ValueError(f'checkpoint missing leaf {name} '
                             '(host shard file absent?)')
        if tuple(a.shape) != tuple(leaf.shape):
            raise ValueError(f'shape mismatch for {name}: '
                             f'{a.shape} saved vs {leaf.shape} expected')
        out.append(a)
    treedef = jax.tree_util.tree_structure(tree_like)
    return jax.tree_util.tree_unflatten(treedef, out), manifest.get('extra', {})


class CheckpointManager:
    """Async keep-K checkpoint manager with auto-resume."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 keep_every: int = 0, host_id: int = 0, num_hosts: int = 1,
                 metrics=None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.keep_every = keep_every
        self.host_id = host_id
        self.num_hosts = num_hosts
        if metrics is None:
            from repro.obs.metrics import Registry
            metrics = Registry()
        self.metrics = metrics
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- discovery ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        steps = []
        for d in self.dir.glob('step_*'):
            if d.is_dir() and not d.name.endswith('.tmp') \
                    and (d / 'manifest.json').exists():
                steps.append(int(d.name.split('_')[1]))
        return sorted(steps)

    def latest(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def manifest_extra(self, step: int) -> Optional[dict]:
        """The ``extra`` metadata a step was saved with, WITHOUT loading any
        array shards — restore paths peek this first to build a shape
        template matching the snapshot's geometry (e.g. a serving stepper's
        dynamic pool capacity).  Returns None when the manifest is missing
        or unreadable (caller falls back a step, as ``restore_latest``
        does)."""
        try:
            with open(self.dir / f'step_{step:010d}' / 'manifest.json') as f:
                return json.load(f).get('extra', {})
        except (OSError, ValueError):
            return None

    # -- save ---------------------------------------------------------------
    def save(self, tree: Any, *, step: int, extra: Optional[dict] = None,
             blocking: bool = False) -> None:
        """Snapshot now, serialize in the background."""
        self.wait()   # at most one in-flight save
        if self._error:
            err, self._error = self._error, None
            raise err
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.dir, snapshot, step=step,
                                host_id=self.host_id,
                                num_hosts=self.num_hosts, extra=extra)
                self._gc()
            except BaseException as e:   # surfaced on next save()/wait()
                self._error = e

        if blocking:
            work()
            if self._error:
                err, self._error = self._error, None
                raise err
        else:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _gc(self) -> None:
        if self.host_id != 0:
            return
        steps = self.all_steps()
        protected = set(steps[-self.keep:]) if self.keep else set(steps)
        if self.keep_every:
            protected |= {s for s in steps if s % self.keep_every == 0}
        for s in steps:
            if s not in protected:
                try:
                    shutil.rmtree(self.dir / f'step_{s:010d}')
                except OSError as e:
                    # a GC failure silently accumulating stale checkpoints
                    # is a disk-full incident waiting to happen — surface it
                    self.metrics.counter(
                        'ckpt.gc_errors',
                        'failed checkpoint garbage collections').inc()
                    warnings.warn(f'checkpoint GC failed for step {s}: {e}',
                                  RuntimeWarning, stacklevel=2)

    # -- restore ------------------------------------------------------------
    def restore_latest(self, tree_like: Any) -> Optional[tuple]:
        """(tree, step, extra) of the newest complete checkpoint, or None."""
        self.wait()
        for step in reversed(self.all_steps()):
            try:
                tree, extra = load_checkpoint(self.dir, tree_like, step=step)
                return tree, step, extra
            except Exception as e:   # corrupt / partial: fall back one step
                self.metrics.counter(
                    'ckpt.restore_fallback',
                    'checkpoints skipped as unreadable at restore').inc()
                warnings.warn(f'checkpoint step {step} unreadable ({e}); '
                              'falling back to previous',
                              RuntimeWarning, stacklevel=2)
        return None
