"""Batched serving example: continuous-batching decode over request traffic.

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-360m

Admits a queue of synthetic requests into a fixed number of KV-cache slots,
refilling slots as requests finish (continuous batching), and reports
throughput.  Works for every assigned architecture (--arch), including the
SSM/hybrid families whose decode state is recurrent rather than KV.
"""
import argparse

from repro.launch.serve import run


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--arch', default='smollm-360m')
    ap.add_argument('--slots', type=int, default=4)
    ap.add_argument('--requests', type=int, default=8)
    ap.add_argument('--max-new', type=int, default=12)
    args = ap.parse_args()
    run(args.arch, slots=args.slots, n_requests=args.requests,
        prompt_len=6, max_new=args.max_new, max_seq=128)


if __name__ == '__main__':
    main()
