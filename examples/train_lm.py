"""End-to-end LM training driver (the deliverable-(b) training example).

Default: a ~100M-parameter smollm-family model for a few hundred steps on
the synthetic token stream, with checkpoints + auto-resume.  On this CPU
container a smaller default is more practical; pass --d-model 768
--layers 12 --steps 300 to run the full ~100M configuration.

    PYTHONPATH=src python examples/train_lm.py --steps 60
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import train
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument('--steps', type=int, default=60)
    ap.add_argument('--d-model', type=int, default=256)
    ap.add_argument('--layers', type=int, default=4)
    ap.add_argument('--batch', type=int, default=4)
    ap.add_argument('--seq', type=int, default=256)
    ap.add_argument('--ckpt-dir', default='/tmp/repro_train_lm')
    args = ap.parse_args()

    # a right-sized smollm-family config (~100M at 768/12)
    import repro.configs.smollm_360m as sm
    cfg = dataclasses.replace(
        sm.CONFIG, n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        d_ff=int(args.d_model * 8 / 3) // 64 * 64, head_dim=0,
        vocab=8192, dtype='float32', remat=False)
    n_params = sum(x.size for x in jax.tree.leaves(
        registry.init_params(jax.random.PRNGKey(0), cfg)))
    print(f'model: {cfg.n_layers}L d={cfg.d_model} -> {n_params / 1e6:.1f}M params')

    from repro.data.tokens import TokenStream
    from repro.optim import adam, schedule
    ctx = registry.make_ctx(None, cfg)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    acfg = adam.AdamConfig(lr=1e-3)
    mod = registry.module_for(cfg)

    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: mod.train_loss(p, batch, cfg, ctx))(params)
        lr = schedule.linear_warmup_cosine(opt.step, warmup_steps=20,
                                           total_steps=args.steps)
        params, opt, gnorm = adam.step(params, grads, opt, acfg, lr_scale=lr)
        return params, opt, loss

    jstep = jax.jit(step)
    opt = adam.init(params, acfg)
    stream = TokenStream(seed=0, global_batch=args.batch, seq=args.seq,
                         vocab=cfg.vocab)
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    for i in range(args.steps):
        params, opt, loss = jstep(params, opt, stream.next())
        if i % 10 == 0:
            print(f'step {i:4d}  loss {float(loss):.4f}')
        if (i + 1) % 50 == 0:
            mgr.save((params, opt), step=i + 1,
                     extra={'stream': stream.state_dict()})
    mgr.wait()
    print(f'done; checkpoints: {mgr.all_steps()}')


if __name__ == '__main__':
    main()
