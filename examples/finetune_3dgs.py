"""Cache-aware fine-tuning example (paper Sec. 3.3 / Eqn. 4).

    PYTHONPATH=src python examples/finetune_3dgs.py

Starts from a scene corrupted with oversized Gaussians (the Fig. 13
artifact source), fine-tunes it against rendered targets with the
scale-constrained loss, and shows RC-only rendering quality before/after.
"""
import jax

from repro.core.finetune import FinetuneConfig, finetune
from repro.core.metrics import psnr
from repro.core.pipeline import LuminaConfig, LuminSys, render_frame_baseline
from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory


def rc_quality(scene, cams, gts):
    cfg = LuminaConfig(capacity=384, use_s2=False, use_rc=True)
    sys_ = LuminSys(scene, cfg, cams[0])
    ps, hits = [], []
    for cam, gt in zip(cams, gts):
        img, st = sys_.step(cam)
        ps.append(float(psnr(img, gt)))
        hits.append(float(st.hit_rate))
    return sum(ps) / len(ps), sum(hits[1:]) / max(len(hits) - 1, 1)


def main():
    key = jax.random.PRNGKey(3)
    gt_scene = structured_scene(key, 1500)
    cams = orbit_trajectory(6, fps=30.0, width=96, height_px=96)
    cfg_r = LuminaConfig(capacity=384, use_s2=False, use_rc=False)
    gts = [render_frame_baseline(gt_scene, c, cfg_r)[0] for c in cams]

    start = structured_scene(key, 1500, large_gaussian_frac=0.25)
    p0, h0 = rc_quality(start, cams, gts)
    print(f'before fine-tuning: RC-only PSNR {p0:.2f} dB, hit rate {h0:.2f}')

    fcfg = FinetuneConfig(scale_alpha=8.0, scale_theta=0.03)
    print('fine-tuning with the scale-constrained loss ...')
    tuned, hist = finetune(start, cams, gts, fcfg, cfg_r, steps=60,
                           log_every=20)
    p1, h1 = rc_quality(tuned, cams, gts)
    print(f'after  fine-tuning: RC-only PSNR {p1:.2f} dB, hit rate {h1:.2f}')


if __name__ == '__main__':
    main()
