"""Quickstart: render a scene with and without Lumina's optimizations.

    PYTHONPATH=src python examples/quickstart.py

Builds a procedural Gaussian scene, flies a VR-style camera orbit, and
renders each frame three ways — exact 3DGS, S^2-only, and full Lumina
(S^2 + radiance caching) — reporting quality vs the exact render and the
measured reuse statistics (cache hit rate, integration work avoided).
"""
import jax

from repro.core.metrics import psnr, ssim
from repro.core.pipeline import LuminaConfig, LuminSys, render_frame_baseline
from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory


def main():
    print('building scene (3k Gaussians) ...')
    scene = structured_scene(jax.random.PRNGKey(0), 3000)
    cams = orbit_trajectory(9, width=128, height_px=128)

    variants = {
        'S2-only': LuminaConfig(capacity=1024, window=3, use_rc=False),
        'Lumina (S2+RC)': LuminaConfig(capacity=1024, window=3, use_rc=True),
    }
    for name, cfg in variants.items():
        sys_ = LuminSys(scene, cfg, cams[0])
        print(f'\n--- {name} ---')
        for i, cam in enumerate(cams):
            img, stats = sys_.step(cam)
            exact, _, _, _ = render_frame_baseline(scene, cam, cfg)
            print(f'frame {i}: psnr={float(psnr(img, exact)):6.2f} dB  '
                  f'ssim={float(ssim(img, exact)):.4f}  '
                  f'hit={float(stats.hit_rate):5.2f}  '
                  f'integration avoided={float(stats.saved_frac):5.2f}  '
                  f'sorted={int(stats.sorted_this_frame)}')


if __name__ == '__main__':
    main()
