"""Kernel-level benchmark: chunk-granular compute savings of the Pallas
rasterizer (the TPU analogue of the paper's 55%-computation-avoided claim)
plus ref-vs-kernel agreement.  Chunks processed = the kernel's early-exit
statistic; with RC, phase A + miss-resume chunks replace the full pass."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import radiance_cache as rc
from repro.core.groups import num_groups
from repro.core.pipeline import render_frame_baseline
from repro.core.projection import project
from repro.core.s2 import predict_pose, shared_features, speculative_sort
from repro.core.sorting import sort_scene
from repro.core.tiling import gather_tile_features
from repro.kernels import ops


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 4 if quick else 8
    img = common.IMG
    cams = common.vr_trajectory(frames, img=img)
    cfg = common.default_cfg()
    chunk = 64

    cache = rc.init_cache(num_groups(img, img, cfg.group_tiles), cfg.cache)
    full_chunks, rc_chunks_a, rc_chunks_b = [], [], []
    hits, pixel_saved = [], []
    for cam in cams:
        proj = project(scene, cam)
        lists = sort_scene(proj, img, img, cfg.capacity)
        feats = gather_tile_features(proj, lists)
        _, aux_full, chunks_full = ops.rasterize_full(feats, lists.tiles_x,
                                                      chunk=chunk)
        final, cache, aux, st = ops.rasterize_with_rc(
            feats, lists.tiles_x, lists.tiles_y, cache, cfg.cache,
            cfg.group_tiles, k_record=cfg.k_record, chunk=chunk)
        full_chunks.append(float(np.sum(np.asarray(chunks_full))))
        rc_chunks_a.append(float(st.chunks_prefix))
        rc_chunks_b.append(float(st.chunks_resume))
        hits.append(float(st.hit_rate))
        # per-pixel integration savings (the paper's 55% metric): work done
        # with RC = what the RC pass actually iterated, vs the full pass
        it_full = float(np.asarray(aux_full.n_iterated, np.float64).sum())
        it_rc = float(np.asarray(aux.n_iterated, np.float64).sum())
        pixel_saved.append(1.0 - it_rc / max(it_full, 1.0))

    fc = np.asarray(full_chunks)
    ca, cb = np.asarray(rc_chunks_a), np.asarray(rc_chunks_b)
    px = np.asarray(pixel_saved)
    # frame 0 fills the cache; savings accrue from frame 1 on
    rows = [
        {'metric': 'pixel_savings_%', 'value': 100 * float(px[1:].mean()),
         'note': "paper's metric: ~55% of color integration avoided"},
        {'metric': 'hit_rate_mean', 'value': float(np.mean(hits[1:])),
         'note': 'paper: >50%'},
        {'metric': 'chunks_full_mean', 'value': float(fc.mean()),
         'note': 'tile-granular passes, no RC'},
        {'metric': 'chunks_rc_mean', 'value': float((ca + cb)[1:].mean()),
         'note': 'phase A + miss resume'},
        {'metric': 'chunk_savings_%',
         'value': 100 * float(1 - (ca + cb)[1:].mean() / fc[1:].mean()),
         'note': 'tile-granular: scattered misses force full-tile resume — '
                 'the warp-divergence analogue LuminCore fixes by PE '
                 'remapping (modeled in hwmodel), not realizable at XLA '
                 'tile granularity'},
    ]
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Kernel — chunk-granular RC savings')


if __name__ == '__main__':
    print(main())
