"""Kernel-level benchmark: chunk-granular compute savings of the Pallas
rasterizer (the TPU analogue of the paper's 55%-computation-avoided claim)
plus ref-vs-kernel agreement.  Chunks processed = the kernel's early-exit
statistic; with RC, phase A + the **miss-compacted** resume replace the full
pass.  Compaction (the software analogue of LuminCore's PE remapping) is
what turns the savings real at chunk granularity: without it one scattered
cache miss dragged its whole tile back through the chunk loop and
``chunk_savings_%`` was negative.  CI gates on that metric staying positive.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core import radiance_cache as rc
from repro.core.groups import num_groups
from repro.core.pipeline import render_frame_baseline
from repro.core.projection import project
from repro.core.s2 import predict_pose, shared_features, speculative_sort
from repro.core.sorting import sort_scene
from repro.core.tiling import gather_tile_features
from repro.kernels import ops


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 4 if quick else 8
    img = common.IMG
    cams = common.vr_trajectory(frames, img=img)
    cfg = common.default_cfg()
    chunk = 64

    cache = rc.init_cache(num_groups(img, img, cfg.group_tiles), cfg.cache)
    full_chunks, rc_chunks_a, rc_chunks_b = [], [], []
    hits, pixel_saved = [], []
    for cam in cams:
        proj = project(scene, cam)
        lists = sort_scene(proj, img, img, cfg.capacity)
        feats = gather_tile_features(proj, lists)
        _, aux_full, chunks_full = ops.rasterize_full(feats, lists.tiles_x,
                                                      chunk=chunk)
        final, cache, aux, st = ops.rasterize_with_rc(
            feats, lists.tiles_x, lists.tiles_y, cache, cfg.cache,
            cfg.group_tiles, k_record=cfg.k_record, chunk=chunk)
        full_chunks.append(float(np.sum(np.asarray(chunks_full))))
        rc_chunks_a.append(float(st.chunks_prefix))
        rc_chunks_b.append(float(st.chunks_resume))
        hits.append(float(st.hit_rate))
        # per-pixel integration savings (the paper's 55% metric): work done
        # with RC = what the RC pass actually iterated, vs the full pass
        it_full = float(np.asarray(aux_full.n_iterated, np.float64).sum())
        it_rc = float(np.asarray(aux.n_iterated, np.float64).sum())
        pixel_saved.append(1.0 - it_rc / max(it_full, 1.0))

    fc = np.asarray(full_chunks)
    ca, cb = np.asarray(rc_chunks_a), np.asarray(rc_chunks_b)
    px = np.asarray(pixel_saved)
    # frame 0 fills the cache; savings accrue from frame 1 on
    rows = [
        {'metric': 'pixel_savings_%', 'value': 100 * float(px[1:].mean()),
         'note': "paper's metric: ~55% of color integration avoided"},
        {'metric': 'hit_rate_mean', 'value': float(np.mean(hits[1:])),
         'note': 'paper: >50%'},
        {'metric': 'chunks_full_mean', 'value': float(fc.mean()),
         'note': 'count-capped full pass, no RC (the honest baseline: it '
                 'shares the early-exit and per-tile chunk caps)'},
        {'metric': 'chunks_rc_prefix_mean', 'value': float(ca[1:].mean()),
         'note': 'phase A (stop at k): tiles exit once every pixel fills '
                 'its record or terminates'},
        {'metric': 'chunks_rc_resume_mean', 'value': float(cb[1:].mean()),
         'note': 'miss-compacted phase B: scales with the miss count, not '
                 'the tile count (PE-remap analogue)'},
        {'metric': 'chunk_savings_%',
         'value': 100 * float(1 - (ca + cb)[1:].mean() / fc[1:].mean()),
         'note': 'measured chunk-granular saving of A + compacted B vs the '
                 'full pass — realized on-device, no longer only modeled in '
                 'hwmodel; CI fails if this goes negative'},
    ]
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Kernel — chunk-granular RC savings')


if __name__ == '__main__':
    print(main())
