"""BENCH regression gating: diff fresh benchmark runs against the
committed baseline with per-metric tolerance bands.

``BENCH_serve.json`` / ``BENCH_kernel.json`` at the repo root are the
committed perf trajectory; CI regenerates them on every build.  This module
compares the fresh payloads against the baseline (the committed copy at a
git ref, ``HEAD`` by default) metric by metric and **fails on regressions**
with a readable per-metric diff, so a PR that quietly halves
``chunk_savings_%`` or serializes the threaded host pipeline back into the
render tick is caught by the build, not by the next person rereading BENCH
JSON by hand.

Rows are matched by identity (viewers / mode / backend / viewers_per_scene
/ driver / stagger / fault_rate / devices / pace / oversub / stream_budget
for serve; metric name for kernel).  A missing identity key on either side
takes its default (``devices`` defaults to 1), so single-device baselines
recorded before the fleet axis existed still compare.

**Missing-row semantics.**  Metric pairs are gated over the intersection,
but a *baseline row with no fresh counterpart is itself a regression*: a
deleted bench cell silently un-gates every metric it carried, which is
exactly the failure mode this module exists to catch.  The carve-outs, in
precedence order:

* rows listed in ``RETIRED_ROWS`` (an identity-subset allowlist) — retiring
  a bench cell is a deliberate, reviewable edit to this file;
* rows matched by the ``allow_missing`` parameter of ``check_payloads``
  (the programmatic form of the same allowlist, for callers gating partial
  payloads on purpose);
* when the fresh payload is a ``--quick`` run (``payload['quick']``),
  baseline rows stamped ``quick_row: false`` by the full bench run — a
  quick run deliberately measures fewer rows, and the full run records
  which ones via the ``_cell_specs(quick)`` id-set.  Baseline rows
  *without* the stamp count as quick-measured, so a quick fresh payload
  still fails when one of its own rows disappears.

Fresh-only rows (new bench cells) are reported and skipped — they gate
once committed.  Tolerance bands are
deliberately wide for wall-clock metrics (the container clock is noisy and
quick runs render fewer frames) and tight for structural ones:

    fps_per_viewer   may drop to 50% of baseline  (catches serialization
                     pathologies, tolerates CI noise)
    p95_frame_ms     may grow to 2.5x baseline
    host_overlap     must stay positive wherever the baseline is, and
                     above 10% of it
    hit_rate         may drop 10% relative (cache decisions are
                     deterministic; this is a structural metric)
    state_alloc_bytes  may grow at most 25% over baseline (a hard ceiling
                     on dropless-allocation creep: buckets that stop
                     shrinking double the footprint, not +25%)
    chunk_savings_%  must stay positive and above 10% of baseline

Usage::

    PYTHONPATH=src python -m benchmarks.history --check
    PYTHONPATH=src python -m benchmarks.history --check --suite serve \\
        --fresh /tmp/BENCH_serve.json --baseline BENCH_serve.json
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import subprocess
from pathlib import Path
from typing import Optional

REPO_ROOT = Path(__file__).resolve().parent.parent
SUITES = ('serve', 'kernel')

# row-identity keys per suite (missing keys default, so older payloads
# still match)
ROW_KEYS = {
    'serve': (('viewers', None), ('mode', None), ('backend', None),
              ('viewers_per_scene', 1), ('driver', 'sync'), ('stagger', 0),
              ('fault_rate', 0.0), ('devices', 1), ('pace', 1),
              ('oversub', 0), ('stream_budget', 0)),
    'kernel': (('metric', None),),
}

# Baseline rows retired on purpose: identity-subset dicts matched against
# baseline row ids (every listed key must equal the row's value).  Adding
# an entry here is the explicit, reviewable act the missing-row gate
# forces — without it a deleted bench cell silently un-gates its metrics.
RETIRED_ROWS = {
    'serve': (),
    'kernel': (),
}

# degraded-mode rows (fault_rate > 0) time watchdog waits, retry backoff
# and inline replans on a noisy container clock: wall-clock tolerances
# widen by this factor, and host_overlap is not gated at all (inline
# degraded ticks legitimately overlap nothing)
FAULT_ROW_WIDEN = 2.0


@dataclasses.dataclass(frozen=True)
class Band:
    """Tolerance band for one gated metric.

    ``rel_tol`` is the allowed relative regression vs baseline (0.5 = the
    fresh value may be 50% worse).  ``abs_floor`` is a hard floor the fresh
    value must stay strictly above — applied only where the baseline itself
    clears it (a sync row's ``host_overlap`` of 0.0 is not a regression).
    """

    metric: str
    higher_is_better: bool
    rel_tol: float
    abs_floor: Optional[float] = None


BANDS = {
    'serve': (
        Band('fps_per_viewer', higher_is_better=True, rel_tol=0.5),
        Band('p95_frame_ms', higher_is_better=False, rel_tol=1.5),
        Band('host_overlap', higher_is_better=True, rel_tol=0.9,
             abs_floor=0.0),
        Band('hit_rate', higher_is_better=True, rel_tol=0.1),
        # allocated state bytes are deterministic (capacity buckets over a
        # deterministic schedule), but quick CI runs render fewer frames
        # and may peak at one bucket below the full run: gate growth with
        # a modest band — a pool that stops shrinking doubles, not +25%
        Band('state_alloc_bytes', higher_is_better=False, rel_tol=0.25),
    ),
    'kernel': (
        Band('chunk_savings_%', higher_is_better=True, rel_tol=0.9,
             abs_floor=0.0),
        Band('hit_rate_mean', higher_is_better=True, rel_tol=0.1),
    ),
}


def _row_id(suite: str, row: dict) -> tuple:
    return tuple(row.get(key, default) for key, default in ROW_KEYS[suite])


def _row_metrics(suite: str, row: dict) -> dict:
    """Gateable metric -> value view of one row (kernel rows are one
    (metric, value) pair each; serve rows carry their metrics inline)."""
    if suite == 'kernel':
        return {row['metric']: row['value']}
    return row


def _fmt_id(suite: str, rid: tuple) -> str:
    parts = [f'{key}={val}' for (key, _), val in zip(ROW_KEYS[suite], rid)]
    return f"{suite}[{' '.join(parts)}]"


def _matches_spec(suite: str, rid: tuple, spec: dict) -> bool:
    keys = [key for key, _ in ROW_KEYS[suite]]
    return all(k in keys and rid[keys.index(k)] == v
               for k, v in spec.items())


def check_payloads(suite: str, baseline: dict, fresh: dict,
                   allow_missing: tuple = ()) -> tuple[list, list]:
    """Gate ``fresh`` rows against matching ``baseline`` rows.

    Returns ``(violations, report_lines)`` — human-readable lines for every
    gated metric, violations repeated in the first list.  Pure function of
    the two payloads (the unit tests drive it with synthetic degradations).

    Baseline rows absent from ``fresh`` are regressions (a dropped bench
    cell) unless retired via ``RETIRED_ROWS``, matched by an
    ``allow_missing`` identity-subset dict, or — for ``--quick`` fresh
    payloads — stamped ``quick_row: false`` by the full bench run (see the
    module docstring's missing-row semantics).
    """
    base_rows = {_row_id(suite, r): r for r in baseline['rows']}
    violations, report = [], []
    gated = 0
    for row in fresh['rows']:
        rid = _row_id(suite, row)
        base = base_rows.get(rid)
        if base is None:
            report.append(f'{_fmt_id(suite, rid)}: no baseline row '
                          f'(skipped)')
            continue
        fresh_m = _row_metrics(suite, row)
        base_m = _row_metrics(suite, base)
        faulted = bool(row.get('fault_rate', 0.0))
        for band in BANDS[suite]:
            if faulted and band.metric == 'host_overlap':
                continue
            bv, fv = base_m.get(band.metric), fresh_m.get(band.metric)
            if not isinstance(bv, (int, float)) \
                    or not isinstance(fv, (int, float)):
                continue
            rel_tol = band.rel_tol * (FAULT_ROW_WIDEN if faulted else 1.0)
            gated += 1
            problems = []
            if band.abs_floor is not None and bv > band.abs_floor \
                    and fv <= band.abs_floor:
                problems.append(f'fell to {fv:.4g} '
                                f'(hard floor {band.abs_floor:g})')
            if band.higher_is_better:
                allowed = bv * max(0.0, 1.0 - rel_tol)
                if fv < allowed:
                    problems.append(f'below tolerance '
                                    f'{allowed:.4g} (= baseline '
                                    f'- {rel_tol:.0%})')
            else:
                allowed = bv * (1.0 + rel_tol)
                if fv > allowed:
                    problems.append(f'above tolerance '
                                    f'{allowed:.4g} (= baseline '
                                    f'+ {rel_tol:.0%})')
            line = (f'{_fmt_id(suite, rid)} {band.metric}: '
                    f'{fv:.4g} vs baseline {bv:.4g}')
            if problems:
                line += ' REGRESSED: ' + '; '.join(problems)
                violations.append(line)
            else:
                line += ' ok'
            report.append(line)
    # baseline rows the fresh payload no longer measures: regressions
    # unless retired, explicitly allowed, or full-run-only vs a quick fresh
    fresh_ids = {_row_id(suite, r) for r in fresh['rows']}
    quick_fresh = bool(fresh.get('quick'))
    for rid, base in base_rows.items():
        if rid in fresh_ids:
            continue
        fid = _fmt_id(suite, rid)
        if any(_matches_spec(suite, rid, spec)
               for spec in RETIRED_ROWS[suite]):
            report.append(f'{fid}: baseline row retired (RETIRED_ROWS)')
            continue
        if any(_matches_spec(suite, rid, spec) for spec in allow_missing):
            report.append(f'{fid}: baseline row allowed missing '
                          f'(allow_missing)')
            continue
        if quick_fresh and not base.get('quick_row', True):
            report.append(f'{fid}: full-run-only row, fresh payload is '
                          f'--quick (skipped)')
            continue
        line = (f'{fid}: baseline row MISSING from fresh payload '
                f'REGRESSED: dropped bench cell? (retire it explicitly '
                f'via RETIRED_ROWS)')
        violations.append(line)
        report.append(line)
    if not gated:
        line = f'{suite}: no gateable metric pairs between payloads'
        violations.append(line)
        report.append(line)
    return violations, report


def load_baseline(suite: str, ref: str = 'HEAD') -> dict:
    """The committed BENCH payload at a git ref."""
    out = subprocess.run(
        ['git', '-C', str(REPO_ROOT), 'show', f'{ref}:BENCH_{suite}.json'],
        capture_output=True, text=True, check=True)
    return json.loads(out.stdout)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.strip().splitlines()[0])
    ap.add_argument('--check', action='store_true',
                    help='gate fresh BENCH payloads against the baseline; '
                         'exit 1 on any regression')
    ap.add_argument('--suite', choices=SUITES, action='append',
                    help='suite(s) to gate (default: all)')
    ap.add_argument('--fresh', default=None, metavar='PATH',
                    help='fresh payload path (single --suite only; default '
                         'BENCH_<suite>.json at the repo root)')
    ap.add_argument('--baseline', default=None, metavar='PATH',
                    help='baseline payload path (single --suite only; '
                         'default: the committed copy at --baseline-ref)')
    ap.add_argument('--baseline-ref', default='HEAD',
                    help='git ref holding the committed baseline '
                         '(default HEAD)')
    args = ap.parse_args(argv)
    if not args.check:
        ap.error('nothing to do (pass --check)')
    suites = tuple(args.suite) if args.suite else SUITES
    if (args.fresh or args.baseline) and len(suites) != 1:
        ap.error('--fresh/--baseline need exactly one --suite')

    failed = False
    for suite in suites:
        fresh_path = Path(args.fresh) if args.fresh \
            else REPO_ROOT / f'BENCH_{suite}.json'
        fresh = json.loads(fresh_path.read_text())
        if args.baseline:
            baseline = json.loads(Path(args.baseline).read_text())
        else:
            baseline = load_baseline(suite, args.baseline_ref)
        violations, report = check_payloads(suite, baseline, fresh)
        print(f'== {suite}: fresh {fresh_path} vs baseline '
              f'{args.baseline or args.baseline_ref} ==')
        for line in report:
            print('  ' + line)
        if violations:
            failed = True
            print(f'  -> {len(violations)} regression(s)')
        else:
            print('  -> within tolerance')
    return 1 if failed else 0


if __name__ == '__main__':
    raise SystemExit(main())
