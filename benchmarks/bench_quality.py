"""Fig. 20 — rendering quality of S2-only / RC-only / Lumina / DS-2 against
the exact 3DGS baseline, on VR-rate (90 FPS, synthetic setting) and
capture-rate (30 FPS, real setting) trajectories.  PSNR + SSIM.  The paper's
claims: S2-only ~= baseline, RC-only within ~0.2 dB, Lumina within ~0.3 dB,
DS-2 1.0-1.4 dB WORSE.  (LPIPS omitted: needs pretrained VGG — DESIGN.md.)

The ``Stream-LOD`` row is the streaming residency manager's LOD axis
(``repro.serve.streaming`` / ``data.scenes``): per frame, chunks within the
near radius render full, chunks out to the LOD radius render only their
significance prefix — the budgeted, approximate sibling of the
significance-exact S² trim.  The run gates its PSNR against
``STREAM_LOD_PSNR_FLOOR`` so an LOD regression (bad prefix ordering, wrong
mask arithmetic) fails the bench, not just drifts the JSON.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import LuminaConfig, render_frame_baseline

# the Stream-LOD geometry: chunk cells of the common bench scene, FULL
# residency within NEAR cells of the camera, significance-prefix LOD out to
# LOD cells (the orbit camera sits ~5-6 cells out, so the scene body lands
# in the LOD band — the axis under test)
STREAM_LOD_CELL = 0.4
STREAM_LOD_NEAR = 4
STREAM_LOD_RADIUS = 12
STREAM_LOD_FRAC = 0.5
# measured ~37.4 dB on the common scene; 30 leaves real headroom while
# still catching a broken prefix order (which costs several dB)
STREAM_LOD_PSNR_FLOOR = 30.0


def _ds2_render(scene, cam, cfg):
    """DS-2 baseline: render 2x downsampled, upsample back (bilinear)."""
    from repro.core.camera import Camera
    import dataclasses
    half = dataclasses.replace(
        cam, width=cam.width // 2, height=cam.height // 2,
        fx=cam.fx / 2, fy=cam.fy / 2, cx=cam.cx / 2, cy=cam.cy / 2)
    img, _, _, _ = render_frame_baseline(scene, half, cfg)
    return jax.image.resize(img, (cam.height, cam.width, 3), 'bilinear')


def _stream_lod_render(scene, cams, cfg):
    """Per-frame LOD-masked renders of the chunk-partitioned scene (the
    pure render is permutation-invariant, so only the trimmed far-cell
    lanes differ from the baseline)."""
    from repro.data.scenes import (chunk_levels, level_rows, masked_scene,
                                   partition_scene)
    ch = partition_scene(scene, cell_size=STREAM_LOD_CELL)
    packed = jax.tree.map(jnp.asarray, ch.packed)
    imgs = []
    for cam in cams:
        lvl = chunk_levels(ch, [np.asarray(cam.position, np.float64)],
                           STREAM_LOD_NEAR, STREAM_LOD_RADIUS)
        rows = level_rows(ch, lvl, STREAM_LOD_FRAC)
        eff = masked_scene(packed, jnp.asarray(rows), ch.chunk_cap)
        img, _, _, _ = render_frame_baseline(eff, cam, cfg)
        imgs.append(img)
    return imgs


def evaluate(scene, cams, variants: dict) -> list[dict]:
    rows = []
    gts = []
    cfg0 = common.quality_cfg(use_s2=False, use_rc=False)
    for cam in cams:
        gt, _, _, _ = render_frame_baseline(scene, cam, cfg0)
        gts.append(gt)
    for name, cfg in variants.items():
        if name == 'DS-2':
            imgs = [_ds2_render(scene, cam, cfg0) for cam in cams]
            hits = [0.0] * len(cams)
        elif name == 'Stream-LOD':
            imgs = _stream_lod_render(scene, cams, cfg0)
            hits = [0.0] * len(cams)
        else:
            imgs, stats, _ = common.run_sequence(scene, cams, cfg)
            hits = [float(s.hit_rate) for s in stats]
        ps = [float(psnr(i, g)) for i, g in zip(imgs, gts)]
        ss = [float(ssim(i, g)) for i, g in zip(imgs, gts)]
        rows.append({'variant': name,
                     'psnr_db': float(np.mean(ps)),
                     'ssim': float(np.mean(ss)),
                     'hit_rate': float(np.mean(hits[1:])) if len(hits) > 1 else 0.0})
    return rows


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 6 if quick else common.FRAMES
    variants = {
        'S2-only': common.quality_cfg(use_s2=True, use_rc=False),
        'RC-only': common.quality_cfg(use_s2=False, use_rc=True),
        'Lumina': common.quality_cfg(use_s2=True, use_rc=True),
        'DS-2': common.quality_cfg(use_s2=False, use_rc=False),
        'Stream-LOD': common.quality_cfg(use_s2=False, use_rc=False),
    }
    rows = []
    for setting, cams in (('vr_90fps', common.vr_trajectory(frames)),
                          ('real_30fps', common.real_trajectory(frames))):
        if quick and setting == 'real_30fps':
            continue
        for r in evaluate(scene, cams, variants):
            rows.append({'setting': setting} | r)
    # streaming LOD gate: the far-cell significance prefix must stay above
    # the PSNR floor (a bad prefix ordering or mask regression fails here)
    for r in rows:
        if r['variant'] == 'Stream-LOD':
            assert r['psnr_db'] >= STREAM_LOD_PSNR_FLOOR, (
                f"Stream-LOD fell below the PSNR floor: "
                f"{r['psnr_db']:.2f} dB < {STREAM_LOD_PSNR_FLOOR} dB "
                f"({r['setting']})")
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.20 — quality (PSNR/SSIM vs exact baseline)')


if __name__ == '__main__':
    print(main())
