"""Fig. 20 — rendering quality of S2-only / RC-only / Lumina / DS-2 against
the exact 3DGS baseline, on VR-rate (90 FPS, synthetic setting) and
capture-rate (30 FPS, real setting) trajectories.  PSNR + SSIM.  The paper's
claims: S2-only ~= baseline, RC-only within ~0.2 dB, Lumina within ~0.3 dB,
DS-2 1.0-1.4 dB WORSE.  (LPIPS omitted: needs pretrained VGG — DESIGN.md.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.metrics import psnr, ssim
from repro.core.pipeline import LuminaConfig, render_frame_baseline


def _ds2_render(scene, cam, cfg):
    """DS-2 baseline: render 2x downsampled, upsample back (bilinear)."""
    from repro.core.camera import Camera
    import dataclasses
    half = dataclasses.replace(
        cam, width=cam.width // 2, height=cam.height // 2,
        fx=cam.fx / 2, fy=cam.fy / 2, cx=cam.cx / 2, cy=cam.cy / 2)
    img, _, _, _ = render_frame_baseline(scene, half, cfg)
    return jax.image.resize(img, (cam.height, cam.width, 3), 'bilinear')


def evaluate(scene, cams, variants: dict) -> list[dict]:
    rows = []
    gts = []
    cfg0 = common.quality_cfg(use_s2=False, use_rc=False)
    for cam in cams:
        gt, _, _, _ = render_frame_baseline(scene, cam, cfg0)
        gts.append(gt)
    for name, cfg in variants.items():
        if name == 'DS-2':
            imgs = [_ds2_render(scene, cam, cfg0) for cam in cams]
            hits = [0.0] * len(cams)
        else:
            imgs, stats, _ = common.run_sequence(scene, cams, cfg)
            hits = [float(s.hit_rate) for s in stats]
        ps = [float(psnr(i, g)) for i, g in zip(imgs, gts)]
        ss = [float(ssim(i, g)) for i, g in zip(imgs, gts)]
        rows.append({'variant': name,
                     'psnr_db': float(np.mean(ps)),
                     'ssim': float(np.mean(ss)),
                     'hit_rate': float(np.mean(hits[1:])) if len(hits) > 1 else 0.0})
    return rows


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 6 if quick else common.FRAMES
    variants = {
        'S2-only': common.quality_cfg(use_s2=True, use_rc=False),
        'RC-only': common.quality_cfg(use_s2=False, use_rc=True),
        'Lumina': common.quality_cfg(use_s2=True, use_rc=True),
        'DS-2': common.quality_cfg(use_s2=False, use_rc=False),
    }
    rows = []
    for setting, cams in (('vr_90fps', common.vr_trajectory(frames)),
                          ('real_30fps', common.real_trajectory(frames))):
        if quick and setting == 'real_30fps':
            continue
        for r in evaluate(scene, cams, variants):
            rows.append({'setting': setting} | r)
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.20 — quality (PSNR/SSIM vs exact baseline)')


if __name__ == '__main__':
    print(main())
