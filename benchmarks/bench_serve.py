"""Serve -- multi-viewer throughput: batched vs sequential, reference vs pallas, private vs scene-shared state.

Measures end-to-end frames/sec of the render-serving subsystem as the number
of concurrent viewers grows, across three axes:

* **engine** — the pose-cell-scheduled batched stepper (one scene-major
  shade per tick, speculative sorts staggered and shared per pose cell) vs
  per-slot sequential stepping (reference backend only; it is the
  per-viewer-cadence baseline, not a kernel-path vehicle);
* **backend** — the pure-JAX reference shade vs the chunked Pallas kernel
  path (``backend='pallas'``: RC phase A -> LuminCache lookup ->
  miss-compacted resume -> insert), so ``BENCH_serve.json`` records the
  shade-path speedup per viewer count;
* **viewers_per_scene** — fully private state (vps=1, one cache + sort
  buffer per slot) vs scene-shared state (vps=S: one radiance cache and a
  pose-cell sort pool for the whole fleet).  Shared rows come in two
  scenarios: **co-located** (stagger=0, identical trajectories — gates the
  sort-pool collapse: live buffers must drop to the distinct-cell count,
  i.e. 1) and **staggered** (stagger=2 — gates the cache-sharing win: a
  viewer admitted into a warm scene cache must beat the same-stagger
  private baseline's hit rate);
* **driver** — the synchronous virtual-clock host loop vs the threaded
  host pipeline (``repro.serve.events``: admission/eviction/pose-cell
  planning on a worker thread, double-buffered against the async device
  dispatch).  Threaded rows gate ``host_overlap > 0`` — host planning must
  actually hide behind the device step — and report the per-frame p50/p95
  latency an open-loop client sees;
* **dropless allocation** — paced (pace=2) rows priced two ways: a static
  one-slot-per-viewer baseline on worst-case per-scene pools vs the same
  doubled population **oversubscribed** into half the slots on power-of-two
  capacity buckets that track live refcounts.  The run gates (and CI
  re-asserts) that the oversubscribed row admits strictly more viewers per
  allocated state byte, and that dynamic pools allocate strictly less than
  the static reservation (``state_alloc_bytes`` < ``state_reserved_bytes``);
* **fault_rate** — degraded-mode rows: the threaded driver under a seeded
  fault trace (``repro.serve.faults``: transient dispatch failures, worker
  deaths, poisoned frames) reports what recovery costs — fps_per_viewer and
  p95_frame_ms under faults vs the clean row — and the run itself asserts
  every viewer still finished every frame (faults degrade service, never
  drop it).  ``benchmarks.history`` gates these rows with widened
  wall-clock tolerances keyed on ``fault_rate``;
* **stream_budget** — pose-cell scene streaming (``repro.serve.streaming``):
  a co-watching pair served from a byte-budgeted residency arena instead of
  the fully-resident scene.  The row records the resident/arena/full byte
  split and the stream counters, and the run gates zero post-warmup stalls
  with a resident footprint strictly below the full scene — CI re-asserts
  both from ``BENCH_serve.json`` through ``benchmarks.history`` (the budget
  is row identity, so the gate tracks this row across baselines);
* **devices** — the elastic multi-device fleet (``repro.serve.fleet``):
  the same viewer population scene-sharded across N device workers
  (``mode='fleet'``), so the rows price the fleet layer's routing and
  admission overhead against the single-manager baseline.  CI runs on one
  CPU device (workers oversubscribe it), so these rows measure sharding
  overhead, not hardware scaling.  The degraded fleet row injects a
  seeded ``device_loss`` mid-run with a bounded admission queue: it
  reports shed arrivals and surviving-capacity throughput, and the run
  itself asserts every *accepted* viewer finished every frame —
  load-shedding, not admission collapse.

Each row reports the realised sort schedule (the run asserts the cohort
bound, so a regression that reintroduces per-lane sorting fails the
benchmark itself), the per-phase latency split, cache occupancy and the
state-memory footprint (live sort-pool entries x entry bytes + cache
bytes); pallas rows add the sampled per-kernel breakdown.
"""
from __future__ import annotations

import time
import warnings

import jax

from repro.core.pipeline import LuminaConfig
from repro.data.scenes import structured_scene
from repro.obs import metrics as obs_metrics
from repro.serve import faults as serve_faults
from repro.serve import fleet as serve_fleet
from repro.serve.render import build_sessions
from repro.serve.session import SessionManager
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import tick_rollup

WIDTH = 64
GAUSS = 1200
CAPACITY = 192
WINDOW = 4
PROFILE_EVERY = 3   # per-kernel sampling cadence on pallas rows (odd, so
                    # samples do not all land on sort-cohort ticks or, in
                    # --quick runs, on the drained tail)
# streaming row: arena budget in bytes (52 chunk frames of 64 gaussians).
# Sized so the co-watching pair's ~44-chunk working set fits with prefetch
# headroom (stalls stay 0) while the arena stays well below the 87-chunk
# full partition — the row gates resident_bytes < full scene bytes.
STREAM_BUDGET = 52 * 64 * 92


class _Cell:
    """One benchmark cell (viewers x engine x backend x viewers_per_scene),
    re-runnable on its compiled stepper.  The serving work is deterministic;
    the container's wall clock is noisy in multi-second bursts, so ``run()``
    interleaves repetitions ACROSS cells round-robin and each cell keeps its
    fastest repetition — a burst then taxes one repetition of every cell
    instead of every repetition of one cell."""

    FAULT_KINDS = ('dispatch_transient', 'worker_death', 'nan_poison')
    FAULT_WATCHDOG_S = 0.5   # a worker death costs one bounded wait

    def __init__(self, scene, viewers: int, frames: int, mode: str,
                 backend: str, vps: int = 1, stagger: int = 0,
                 driver: str = 'sync', fault_rate: float = 0.0,
                 pace: int = 1, oversub: bool = False,
                 slots: int | None = None, pool_size: int | None = None,
                 sess_vps: int | None = None, stream_budget: int = 0):
        self.viewers, self.frames = viewers, frames
        self.mode, self.backend = mode, backend
        self.vps, self.stagger = vps, stagger
        self.driver = driver
        self.fault_rate = fault_rate
        self.stream_budget = stream_budget
        # dropless-allocation axis: paced viewers (pace >= 2) optionally
        # oversubscribed into fewer physical slots than viewers;
        # ``pool_size`` forces the static worst-case per-scene pool the
        # capacity buckets replaced (the comparison baseline); ``sess_vps``
        # overrides the session-side scene grouping when the slot count
        # diverges from the viewer count
        self.pace, self.oversub = pace, oversub
        self.slots = viewers if slots is None else slots
        self.pool_size = pool_size
        self.sess_vps = vps if sess_vps is None else sess_vps
        cfg = LuminaConfig(capacity=CAPACITY, window=WINDOW, backend=backend)
        profile = PROFILE_EVERY if backend == 'pallas' else 0
        cam0 = build_sessions(1, 1, width=WIDTH)[0].cams[0]
        if mode == 'sequential':
            self.stepper = SequentialStepper(scene, cfg, cam0, self.slots,
                                             profile_every=profile)
        else:
            streaming = None
            if stream_budget:
                from repro.data.scenes import partition_scene
                from repro.serve.streaming import ResidencyManager
                chunked = partition_scene(scene, cell_size=0.4,
                                          chunk_cap=64)
                streaming = ResidencyManager(chunked, near_radius=3,
                                             lod_radius=5,
                                             budget_bytes=stream_budget)
            self.stepper = BatchedStepper(scene, cfg, cam0, self.slots,
                                          profile_every=profile,
                                          viewers_per_scene=vps,
                                          pool_size=pool_size,
                                          streaming=streaming)
        self.best = None

    def run_once(self) -> None:
        # fresh state on the compiled stepper: shared-mode admits keep scene
        # caches warm by design, so repetitions must reset explicitly
        self.stepper.reset()
        sessions = build_sessions(self.viewers, self.frames, width=WIDTH,
                                  stagger=self.stagger,
                                  viewers_per_scene=self.sess_vps,
                                  paces=([self.pace] * self.viewers
                                         if self.pace > 1 else None))
        injector = serve_faults.NULL
        if self.fault_rate:
            # the same seeded trace every repetition: degraded rows time
            # one fixed failure schedule, not a fresh dice roll
            horizon = self.viewers * self.stagger + self.frames + 4
            injector = serve_faults.FaultInjector(serve_faults.make_trace(
                self.FAULT_KINDS, horizon, seed=0, rate=self.fault_rate,
                slots=self.viewers))
        mgr = SessionManager(self.stepper, self.slots, injector=injector,
                             watchdog_s=(self.FAULT_WATCHDOG_S
                                         if self.fault_rate else None),
                             oversubscribe=self.oversub)
        for s in sessions:
            mgr.submit(s)
        # warm-up tick compiles the step on the first repetition (and
        # absorbs every sort-on-admit burst); excluded from the timed run
        # and the per-tick sort accounting
        mgr.run_tick()
        prof0 = self.stepper.profile_s
        t0 = time.perf_counter()
        with warnings.catch_warnings():
            if injector.enabled:   # injected deaths warn by design
                warnings.simplefilter('ignore', RuntimeWarning)
            finished = mgr.run(driver=self.driver)
        # per-kernel profiling runs outside the serving work proper;
        # subtract its overhead so fps compares backends, not cadences
        wall = time.perf_counter() - t0 - (self.stepper.profile_s - prof0)
        if injector.enabled:
            # faults degrade service, never drop it
            assert all(s.telemetry.frames == self.frames for s in finished), \
                f'faulted run dropped frames at {self.viewers} viewers'
        rendered = sum(s.telemetry.frames for s in finished) - mgr.tick_log[
            0]['frames'] if mgr.tick_log else 0
        roll = tick_rollup(mgr.tick_log, warmup_ticks=1)

        def _counter(name):
            return mgr.metrics[name].value if name in mgr.metrics else 0

        stats = {'faults_injected': sum(injector.fired_counts().values()),
                 'degraded_ticks': _counter('serve.degraded_ticks'),
                 'retries': _counter('serve.retries')}
        if self.best is None or wall < self.best[1]:
            self.best = (rendered, wall, finished, roll, stats)

    def row(self) -> dict:
        rendered, wall, finished, roll, stats = self.best
        fps = rendered / wall if wall > 0 else float('inf')
        cohort_bound = -(-self.viewers // WINDOW)
        if self.mode == 'batched' and self.stagger == 0 \
                and not self.fault_rate and self.pace == 1:
            # steady-state bound: sort-on-admit is outside the scheduled
            # cohort by design, so staggered-arrival rows (admits landing
            # after the warm-up tick) are exempt — as are faulted rows,
            # whose quarantine re-admits land sort-on-admits mid-run
            assert roll['max_sorts_per_tick'] <= cohort_bound, (
                f"sort scheduler regressed: "
                f"{roll['max_sorts_per_tick']} speculative sorts in one "
                f"tick with {self.viewers} viewers, window {WINDOW} "
                f"(bound ceil(S/window) = {cohort_bound})")
        if self.mode == 'batched' and self.vps > 1 and self.stagger == 0:
            # co-located viewers of one scene must collapse to one live
            # sort buffer per scene — the pool holds O(distinct cells).
            # Oversubscribed slots interleave residue classes at offset
            # cursors, so each scene may hold up to `pace` live entries
            # (one per class), still independent of the viewer count.
            scenes = -(-self.slots // self.vps)
            limit = scenes * (self.pace if self.oversub else 1)
            assert roll['max_sort_pool_live'] <= limit, (
                f"sort pool regressed: {roll['max_sort_pool_live']} live "
                f"buffers for {self.viewers} co-located viewers over "
                f"{scenes} scene(s) (bound {limit})")
        if self.driver == 'threaded' and not self.fault_rate:
            # the async host pipeline must actually hide host planning
            # behind the device step: zero overlap means admission/eviction
            # /pose-cell work serialized back into the render tick (faulted
            # rows are exempt — degraded inline ticks overlap nothing)
            assert roll.get('host_overlap', 0.0) > 0.0, (
                f"threaded host pipeline overlapped nothing at "
                f"{self.viewers} viewers (host {roll.get('host_ms')} "
                f"ms/tick)")
        row = {
            'viewers': self.viewers,
            'mode': self.mode,
            'backend': self.backend,
            'viewers_per_scene': self.vps,
            'driver': self.driver,
            'stagger': self.stagger,
            'fault_rate': self.fault_rate,
            'faults_injected': stats['faults_injected'],
            'degraded_ticks': stats['degraded_ticks'],
            'retries': stats['retries'],
            'pace': self.pace,
            'oversub': int(self.oversub),
            'slots': self.slots,
            'pool': ('dynamic' if (self.mode == 'batched' and self.vps > 1
                                   and self.pool_size is None)
                     else 'static'),
            'window': WINDOW,
            'frames': rendered,
            'wall_s': wall,
            'fps_total': fps,
            'fps_per_viewer': fps / self.viewers,
            'hit_rate': sum(s.telemetry.summary()['hit_rate']
                            for s in finished) / self.viewers,
            'sorts_per_tick': roll['mean_sorts_per_tick'],
            'max_sorts_per_tick': roll['max_sorts_per_tick'],
            'sort_ms': roll['mean_sort_ms'],
            'shade_ms': roll['mean_shade_ms'],
            'kernel_ms': roll['kernel_ms'],
        }
        # uniform columns across engines (fmt_rows wants one schema); the
        # sequential baseline reports no occupancy scan (see its
        # state_metrics docstring)
        for key in ('last_occupancy', 'max_sort_pool_live',
                    'sort_pool_bytes', 'sort_pool_alloc_bytes',
                    'sort_pool_reserved_bytes', 'cache_bytes',
                    'state_bytes', 'state_alloc_bytes',
                    'state_reserved_bytes', 'p50_frame_ms', 'p95_frame_ms',
                    'host_ms', 'host_overlap'):
            row[key] = roll.get(key)
        # streaming axis: the arena budget is row identity (history.py keys
        # on it, defaulting 0 for non-streaming rows/older baselines)
        row['stream_budget'] = self.stream_budget
        for key in ('stream_resident_bytes', 'stream_arena_bytes',
                    'stream_full_bytes', 'stream_stalls',
                    'stream_stalls_tail', 'stream_loads',
                    'stream_prefetch_hits', 'stream_evictions'):
            row[key] = roll.get(key)
        return row


class _FleetCell:
    """One multi-device fleet cell (``repro.serve.fleet``): the viewer
    population scene-sharded across ``devices`` workers behind the shared
    admission queue, driven by the sync fleet oracle (deterministic work —
    the threaded fleet is bit-identical by the conformance suite, so the
    sync rows time the same schedule without thread-scheduling noise).

    ``fault_rate > 0`` seeds a ``device_loss`` trace and bounds the fleet
    queue at ``viewers`` pending seats with two extra arrivals on top, so
    the degraded row demonstrates load-shedding (excess arrivals rejected
    up front, counted) rather than admission collapse (every accepted
    viewer drains — asserted)."""

    def __init__(self, scene, viewers: int, frames: int, devices: int,
                 fault_rate: float = 0.0):
        self.viewers, self.frames = viewers, frames
        self.devices = devices
        self.fault_rate = fault_rate
        self.extra = 2 if fault_rate else 0
        self.slots = -(-viewers // devices)
        cfg = LuminaConfig(capacity=CAPACITY, window=WINDOW,
                           backend='reference')
        cam0 = build_sessions(1, 1, width=WIDTH)[0].cams[0]
        # one stepper per worker, compiled once and reset per repetition
        self.steppers = [BatchedStepper(scene, cfg, cam0, self.slots)
                         for _ in range(devices)]
        self.best = None

    def _fresh_fleet(self, injector):
        workers = []
        for d, stp in enumerate(self.steppers):
            stp.reset()
            mgr = SessionManager(stp, self.slots,
                                 metrics=obs_metrics.Registry())
            workers.append(serve_fleet.FleetWorker(d, None, mgr, None))
        return serve_fleet.FleetManager(
            workers, injector=injector,
            max_pending=self.viewers if self.fault_rate else None)

    def run_once(self) -> None:
        injector = serve_faults.NULL
        if self.fault_rate:
            horizon = 2 * (self.viewers + self.extra) + self.frames + 4
            injector = serve_faults.FaultInjector(serve_faults.make_trace(
                ('device_loss',), horizon, seed=0, rate=self.fault_rate,
                slots=self.devices))
        fm = self._fresh_fleet(injector)
        sessions = build_sessions(self.viewers + self.extra, self.frames,
                                  width=WIDTH)
        for s in sessions:
            fm.submit(s)
        with warnings.catch_warnings():
            if injector.enabled:   # losses on the last device warn
                warnings.simplefilter('ignore', RuntimeWarning)
            # warm-up tick compiles every worker's step on the first
            # repetition; excluded from the timed run
            warm = fm.run_tick()
            t0 = time.perf_counter()
            finished = serve_fleet.SyncFleetDriver(fm).run()
            wall = time.perf_counter() - t0
        # degraded capacity sheds NEW load; accepted viewers always drain
        accepted = self.viewers + self.extra - len(fm.shed)
        assert len(finished) == accepted, (
            f'fleet dropped an accepted viewer: {len(finished)} finished '
            f'vs {accepted} accepted at {self.devices} devices')
        assert all(s.telemetry.frames == self.frames for s in finished), \
            f'fleet run dropped frames at {self.devices} devices'
        rendered = sum(s.telemetry.frames for s in finished) - warm
        roll = tick_rollup(fm.merged_tick_log(), warmup_ticks=1)
        stats = {'alive_devices': len(fm.alive), 'shed': len(fm.shed),
                 'faults_injected': sum(injector.fired_counts().values())}
        if self.best is None or wall < self.best[1]:
            self.best = (rendered, wall, finished, roll, stats)

    def row(self) -> dict:
        rendered, wall, finished, roll, stats = self.best
        fps = rendered / wall if wall > 0 else float('inf')
        row = {
            'viewers': self.viewers,
            'mode': 'fleet',
            'backend': 'reference',
            'viewers_per_scene': 1,
            'driver': 'sync',
            'stagger': 2,
            'fault_rate': self.fault_rate,
            'faults_injected': stats['faults_injected'],
            'degraded_ticks': 0,
            'retries': 0,
            'pace': 1,
            'oversub': 0,
            'slots': self.slots * self.devices,
            'pool': 'static',
            'window': WINDOW,
            'frames': rendered,
            'wall_s': wall,
            'fps_total': fps,
            'fps_per_viewer': fps / self.viewers,
            'hit_rate': sum(s.telemetry.summary()['hit_rate']
                            for s in finished) / max(len(finished), 1),
            'sorts_per_tick': roll['mean_sorts_per_tick'],
            'max_sorts_per_tick': roll['max_sorts_per_tick'],
            'sort_ms': roll['mean_sort_ms'],
            'shade_ms': roll['mean_shade_ms'],
            'kernel_ms': roll['kernel_ms'],
        }
        for key in ('last_occupancy', 'max_sort_pool_live',
                    'sort_pool_bytes', 'sort_pool_alloc_bytes',
                    'sort_pool_reserved_bytes', 'cache_bytes',
                    'state_bytes', 'state_alloc_bytes',
                    'state_reserved_bytes', 'p50_frame_ms', 'p95_frame_ms',
                    'host_ms', 'host_overlap'):
            row[key] = roll.get(key)
        row['stream_budget'] = 0
        # the fleet axis proper (identity key + degraded-mode accounting;
        # history.py matches `devices`, older baselines default it to 1)
        row['devices'] = self.devices
        row['slots_per_device'] = self.slots
        row['alive_devices'] = stats['alive_devices']
        row['shed'] = stats['shed']
        return row


def _cell_specs(quick: bool) -> list[dict]:
    """Pure cell parameterization for a quick or full run (no steppers
    constructed).  Full runs stamp every row with ``quick_row`` — whether a
    ``--quick`` CI run measures the same row identity — by membership in
    the id-set of ``_cell_specs(True)``; ``benchmarks.history`` reads the
    flag to tell *quick run legitimately measures fewer rows* apart from
    *a bench cell was silently dropped*."""
    frames = 4 if quick else 8
    counts = (1, 2) if quick else (1, 2, 4)
    shared_at = counts[-1]      # the viewer count carrying the vps axis
    # (engine, backend) axes; sequential is the per-viewer-cadence baseline
    # and runs the reference backend only
    specs = [dict(kind='cell', viewers=viewers, frames=frames, mode=mode,
                  backend=backend)
             for viewers in counts
             for mode, backend in (('batched', 'reference'),
                                   ('batched', 'pallas'),
                                   ('sequential', 'reference'))]
    # the driver axis: the threaded host pipeline vs the sync virtual clock
    # at every viewer count (batched reference engine — the overlap story
    # is host planning vs the async device dispatch, not the kernel path)
    specs += [dict(kind='cell', viewers=viewers, frames=frames,
                   mode='batched', backend='reference', driver='threaded')
              for viewers in counts]
    # the viewers_per_scene axis at the largest viewer count:
    #  - co-located shared rows (stagger 0) gate the sort-pool collapse
    #  - staggered shared-vs-private pairs gate the cache-sharing hit rate
    for backend in ('reference', 'pallas'):
        specs.append(dict(kind='cell', viewers=shared_at, frames=frames,
                          mode='batched', backend=backend, vps=shared_at,
                          stagger=0))
    specs.append(dict(kind='cell', viewers=shared_at, frames=frames,
                      mode='batched', backend='reference', vps=shared_at,
                      stagger=2))
    specs.append(dict(kind='cell', viewers=shared_at, frames=frames,
                      mode='batched', backend='reference', vps=1,
                      stagger=2))
    # the dropless-allocation axis: one doubled, half-rate (pace 2) viewer
    # population served two ways —
    #  (A) static: one slot per viewer, worst-case per-scene pools
    #      (pool_size=vps, the allocation scheme capacity buckets replaced)
    #  (B) dropless: oversubscribed into HALF the slots (co-residents
    #      interleave on alternating ticks) on capacity-bucketed pools
    # the run gates strictly more admitted viewers per allocated byte on B
    over_v = 2 * shared_at
    specs.append(dict(kind='cell', viewers=over_v, frames=frames,
                      mode='batched', backend='reference', vps=shared_at,
                      stagger=0, pace=2, pool_size=shared_at))
    specs.append(dict(kind='cell', viewers=over_v, frames=frames,
                      mode='batched', backend='reference', vps=shared_at,
                      stagger=0, pace=2, oversub=True, slots=shared_at,
                      sess_vps=over_v))
    # the fault_rate axis: degraded-mode cost on the threaded driver at the
    # largest viewer count (paired with the clean threaded row above)
    for fault_rate in (0.1, 0.3):
        specs.append(dict(kind='cell', viewers=shared_at, frames=frames,
                          mode='batched', backend='reference',
                          driver='threaded', fault_rate=fault_rate))
    # the streaming axis: a co-watching pair over a budgeted residency
    # arena (same identity in quick and full runs, so quick CI gates it
    # against the committed baseline); the run asserts zero post-warmup
    # stalls and a resident footprint strictly below the full scene
    specs.append(dict(kind='cell', viewers=2, frames=frames,
                      mode='batched', backend='reference', vps=2,
                      stagger=0, stream_budget=STREAM_BUDGET))
    # the devices axis: the viewer population at the largest count sharded
    # across the serving fleet (sharding overhead on oversubscribed CPU;
    # these rows carry mode='fleet' so the single-device gates skip them)
    for devices in ((1, 2) if quick else (1, 2, 4)):
        specs.append(dict(kind='fleet', viewers=shared_at, frames=frames,
                          devices=devices))
    # degraded fleet: seeded device_loss against a bounded admission queue —
    # the row must show load-shedding, not admission collapse
    specs.append(dict(kind='fleet', viewers=shared_at, frames=frames,
                      devices=2, fault_rate=0.3))
    return specs


def _spec_row_id(spec: dict) -> tuple:
    """The ``benchmarks.history`` row identity a spec's row will carry
    (fleet cells pin the non-axis keys exactly as ``_FleetCell.row``
    does)."""
    from benchmarks import history
    if spec['kind'] == 'fleet':
        row = {'viewers': spec['viewers'], 'mode': 'fleet',
               'backend': 'reference', 'viewers_per_scene': 1,
               'driver': 'sync', 'stagger': 2,
               'fault_rate': spec.get('fault_rate', 0.0),
               'devices': spec['devices']}
    else:
        row = {'viewers': spec['viewers'], 'mode': spec['mode'],
               'backend': spec['backend'],
               'viewers_per_scene': spec.get('vps', 1),
               'driver': spec.get('driver', 'sync'),
               'stagger': spec.get('stagger', 0),
               'fault_rate': spec.get('fault_rate', 0.0),
               'pace': spec.get('pace', 1),
               'oversub': int(spec.get('oversub', False)),
               'stream_budget': spec.get('stream_budget', 0)}
    return history._row_id('serve', row)


def _make_cell(scene, spec: dict):
    kw = dict(spec)
    kind = kw.pop('kind')
    if kind == 'fleet':
        return _FleetCell(scene, **kw)
    return _Cell(scene, **kw)


def run(quick: bool = False, reps: int = 4):
    from benchmarks import history
    scene = structured_scene(jax.random.PRNGKey(0), GAUSS)
    specs = _cell_specs(quick)
    quick_ids = {_spec_row_id(s) for s in _cell_specs(True)}
    cells = [_make_cell(scene, spec) for spec in specs]
    for _ in range(max(1, reps)):
        for cell in cells:
            cell.run_once()
    rows = [cell.row() for cell in cells]
    for row in rows:
        row['quick_row'] = history._row_id('serve', row) in quick_ids

    # cross-row gate: shared scene caches must serve staggered arrivals at
    # least as well as private ones (the warm-admission win); CI re-asserts
    # this from BENCH_serve.json
    for r in rows:
        if r['viewers_per_scene'] > 1 and r['stagger'] > 0:
            base = [b for b in rows
                    if b['viewers'] == r['viewers']
                    and b['mode'] == r['mode']
                    and b['backend'] == r['backend']
                    and b['stagger'] == r['stagger']
                    and b['viewers_per_scene'] == 1]
            assert base and r['hit_rate'] > base[0]['hit_rate'], (
                f"scene-shared cache lost its hit-rate edge: "
                f"{r['hit_rate']:.3f} (shared) vs "
                f"{base[0]['hit_rate'] if base else float('nan'):.3f} "
                f"(private) at {r['viewers']} viewers")
    # dropless gates (CI re-asserts both from BENCH_serve.json):
    #  1. capacity buckets must track live work — every dynamic co-located
    #     row allocates strictly less than its static worst-case reservation
    for r in rows:
        if r.get('pool') == 'dynamic' and r['stagger'] == 0 \
                and not r.get('oversub'):
            assert r['state_alloc_bytes'] < r['state_reserved_bytes'], (
                f"dropless allocation regressed: dynamic pool allocated "
                f"{r['state_alloc_bytes']} B >= the {r['state_reserved_bytes']}"
                f" B static reservation at {r['viewers']} viewers")
    #  2. the paced oversubscribed row must admit strictly more viewers per
    #     allocated byte than the one-slot-per-viewer static baseline
    over = [r for r in rows if r.get('oversub')]
    base = [r for r in rows
            if r.get('pace', 1) > 1 and not r.get('oversub')]
    assert over and base, 'dropless comparison rows missing'
    o, b = over[0], base[0]
    density_o = o['viewers'] / o['state_alloc_bytes']
    density_b = b['viewers'] / b['state_alloc_bytes']
    assert density_o > density_b, (
        f"oversubscription lost its memory edge: "
        f"{density_o:.3e} viewers/byte (oversubscribed, "
        f"{o['state_alloc_bytes']} B) vs {density_b:.3e} (static, "
        f"{b['state_alloc_bytes']} B) at {o['viewers']} viewers")
    # streaming gates (CI re-asserts both from BENCH_serve.json): a budget
    # sized to the live working set must serve without post-warmup stalls,
    # on a resident footprint strictly below the fully-resident scene
    for r in rows:
        if r.get('stream_budget'):
            assert r['stream_stalls_tail'] == 0, (
                f"streaming stalled in steady state: "
                f"{r['stream_stalls_tail']} post-warmup slot-stalls with "
                f"budget {r['stream_budget']} B")
            assert r['stream_resident_bytes'] < r['stream_full_bytes'], (
                f"streaming kept the whole scene resident: "
                f"{r['stream_resident_bytes']} B resident vs "
                f"{r['stream_full_bytes']} B full scene")
    return rows


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run(), __doc__.strip().splitlines()[0]))


if __name__ == '__main__':
    main()
