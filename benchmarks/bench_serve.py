"""Serve -- multi-viewer throughput: batched vs sequential, reference vs pallas.

Measures end-to-end frames/sec of the render-serving subsystem as the number
of concurrent viewers grows, across two axes:

* **engine** — the cohort-scheduled batched stepper (one vmapped shade per
  tick, speculative sorts staggered so at most ceil(S/window) slots sort per
  tick) vs per-slot sequential stepping (reference backend only; it is the
  per-viewer-cadence baseline, not a kernel-path vehicle);
* **backend** — the pure-JAX reference shade vs the chunked Pallas kernel
  path (``backend='pallas'``: RC phase A -> LuminCache lookup ->
  miss-compacted resume -> insert), so ``BENCH_serve.json`` records the
  shade-path speedup per viewer count.

Each row reports the realised sort schedule (the run asserts the cohort
bound, so a regression that reintroduces per-lane sorting fails the
benchmark itself) and the per-phase latency split; pallas rows add the
sampled per-kernel breakdown (prep/prefix/lookup/resume/insert ms).
"""
from __future__ import annotations

import time

import jax

from repro.core.pipeline import LuminaConfig
from repro.data.scenes import structured_scene
from repro.serve.render import build_sessions
from repro.serve.session import SessionManager
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import tick_rollup

WIDTH = 64
GAUSS = 1200
CAPACITY = 192
WINDOW = 4
PROFILE_EVERY = 3   # per-kernel sampling cadence on pallas rows (odd, so
                    # samples do not all land on sort-cohort ticks or, in
                    # --quick runs, on the drained tail)


class _Cell:
    """One benchmark cell (viewers x engine x backend), re-runnable on its
    compiled stepper.  The serving work is deterministic; the container's
    wall clock is noisy in multi-second bursts, so ``run()`` interleaves
    repetitions ACROSS cells round-robin and each cell keeps its fastest
    repetition — a burst then taxes one repetition of every cell instead of
    every repetition of one cell."""

    def __init__(self, scene, viewers: int, frames: int, mode: str,
                 backend: str):
        self.viewers, self.frames = viewers, frames
        self.mode, self.backend = mode, backend
        cfg = LuminaConfig(capacity=CAPACITY, window=WINDOW, backend=backend)
        engine = SequentialStepper if mode == 'sequential' else BatchedStepper
        profile = PROFILE_EVERY if backend == 'pallas' else 0
        cam0 = build_sessions(1, 1, width=WIDTH)[0].cams[0]
        self.stepper = engine(scene, cfg, cam0, viewers,
                              profile_every=profile)
        self.best = None

    def run_once(self) -> None:
        sessions = build_sessions(self.viewers, self.frames, width=WIDTH,
                                  stagger=0)
        mgr = SessionManager(self.stepper, self.viewers)
        for s in sessions:
            mgr.submit(s)
        # warm-up tick compiles the step on the first repetition (and
        # absorbs every sort-on-admit burst); excluded from the timed run
        # and the per-tick sort accounting
        mgr.run_tick()
        prof0 = self.stepper.profile_s
        t0 = time.perf_counter()
        finished = mgr.run()
        # per-kernel profiling runs outside the serving work proper;
        # subtract its overhead so fps compares backends, not cadences
        wall = time.perf_counter() - t0 - (self.stepper.profile_s - prof0)
        rendered = sum(s.telemetry.frames
                       for s in finished) - self.viewers  # warm-up
        roll = tick_rollup(mgr.tick_log, warmup_ticks=1)
        if self.best is None or wall < self.best[1]:
            self.best = (rendered, wall, finished, roll)

    def row(self) -> dict:
        rendered, wall, finished, roll = self.best
        fps = rendered / wall if wall > 0 else float('inf')
        cohort_bound = -(-self.viewers // WINDOW)
        if self.mode == 'batched':
            assert roll['max_sorts_per_tick'] <= cohort_bound, (
                f"cohort scheduler regressed: "
                f"{roll['max_sorts_per_tick']} speculative sorts in one "
                f"tick with {self.viewers} viewers, window {WINDOW} "
                f"(bound ceil(S/window) = {cohort_bound})")
        return {
            'viewers': self.viewers,
            'mode': self.mode,
            'backend': self.backend,
            'window': WINDOW,
            'frames': rendered,
            'wall_s': wall,
            'fps_total': fps,
            'fps_per_viewer': fps / self.viewers,
            'hit_rate': sum(s.telemetry.summary()['hit_rate']
                            for s in finished) / self.viewers,
            'sorts_per_tick': roll['mean_sorts_per_tick'],
            'max_sorts_per_tick': roll['max_sorts_per_tick'],
            'sort_ms': roll['mean_sort_ms'],
            'shade_ms': roll['mean_shade_ms'],
            'kernel_ms': roll['kernel_ms'],
        }


def run(quick: bool = False, reps: int = 4):
    frames = 4 if quick else 8
    counts = (1, 2) if quick else (1, 2, 4)
    scene = structured_scene(jax.random.PRNGKey(0), GAUSS)
    # (engine, backend) axes; sequential is the per-viewer-cadence baseline
    # and runs the reference backend only
    variants = (('batched', 'reference'), ('batched', 'pallas'),
                ('sequential', 'reference'))
    cells = [_Cell(scene, viewers, frames, mode, backend)
             for viewers in counts for mode, backend in variants]
    for _ in range(max(1, reps)):
        for cell in cells:
            cell.run_once()
    return [cell.row() for cell in cells]


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run(), __doc__.strip().splitlines()[0]))


if __name__ == '__main__':
    main()
