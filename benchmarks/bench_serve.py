"""Serve -- multi-viewer throughput: batched vs sequential stepping.

Measures end-to-end frames/sec of the render-serving subsystem as the number
of concurrent viewers grows, once with the vmapped batched stepper (one
jitted call advances every slot) and once with per-slot sequential stepping.
The batched column is the one that matters for the ROADMAP's many-users
goal: its per-viewer cost should fall as slots fill, while sequential cost
stays flat.
"""
from __future__ import annotations

import time

import jax

from repro.core.pipeline import LuminaConfig
from repro.data.scenes import structured_scene
from repro.serve.render import build_sessions
from repro.serve.session import SessionManager
from repro.serve.stepper import BatchedStepper, SequentialStepper

WIDTH = 64
GAUSS = 1200
CAPACITY = 192


def _serve_once(scene, cfg, viewers: int, frames: int, sequential: bool):
    sessions = build_sessions(viewers, frames, width=WIDTH, stagger=0)
    engine = SequentialStepper if sequential else BatchedStepper
    stepper = engine(scene, cfg, sessions[0].cams[0], viewers)
    mgr = SessionManager(stepper, viewers)
    for s in sessions:
        mgr.submit(s)
    # warm-up tick compiles the step; excluded from the timed run
    mgr.run_tick()
    t0 = time.perf_counter()
    finished = mgr.run()
    wall = time.perf_counter() - t0
    rendered = sum(s.telemetry.frames for s in finished) - viewers  # warm-up
    return rendered, wall, finished


def run(quick: bool = False):
    frames = 4 if quick else 8
    counts = (1, 2) if quick else (1, 2, 4)
    scene = structured_scene(jax.random.PRNGKey(0), GAUSS)
    cfg = LuminaConfig(capacity=CAPACITY, window=4)
    rows = []
    for viewers in counts:
        for sequential in (False, True):
            rendered, wall, finished = _serve_once(
                scene, cfg, viewers, frames, sequential)
            fps = rendered / wall if wall > 0 else float('inf')
            rows.append({
                'viewers': viewers,
                'mode': 'sequential' if sequential else 'batched',
                'frames': rendered,
                'wall_s': wall,
                'fps_total': fps,
                'fps_per_viewer': fps / viewers,
                'hit_rate': sum(s.telemetry.summary()['hit_rate']
                                for s in finished) / viewers,
            })
    return rows


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run(), __doc__.strip().splitlines()[0]))


if __name__ == '__main__':
    main()
