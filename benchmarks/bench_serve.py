"""Serve -- multi-viewer throughput: batched vs sequential stepping.

Measures end-to-end frames/sec of the render-serving subsystem as the number
of concurrent viewers grows, once with the cohort-scheduled batched stepper
(one vmapped shade per tick, speculative sorts staggered so at most
ceil(S/window) slots sort per tick) and once with per-slot sequential
stepping.  The batched column is the one that matters for the ROADMAP's
many-users goal: its per-viewer cost should fall as slots fill, while
sequential cost stays flat.  Each row also reports the realised sort
schedule (mean/max speculative sorts per tick after warmup) and the
per-phase latency split — the run asserts the cohort bound, so a regression
that reintroduces per-lane sorting fails the benchmark itself.
"""
from __future__ import annotations

import time

import jax

from repro.core.pipeline import LuminaConfig
from repro.data.scenes import structured_scene
from repro.serve.render import build_sessions
from repro.serve.session import SessionManager
from repro.serve.stepper import BatchedStepper, SequentialStepper
from repro.serve.telemetry import tick_rollup

WIDTH = 64
GAUSS = 1200
CAPACITY = 192
WINDOW = 4


def _serve_once(scene, cfg, viewers: int, frames: int, sequential: bool):
    sessions = build_sessions(viewers, frames, width=WIDTH, stagger=0)
    engine = SequentialStepper if sequential else BatchedStepper
    stepper = engine(scene, cfg, sessions[0].cams[0], viewers)
    mgr = SessionManager(stepper, viewers)
    for s in sessions:
        mgr.submit(s)
    # warm-up tick compiles the step (and absorbs every sort-on-admit burst);
    # excluded from the timed run and the per-tick sort accounting
    mgr.run_tick()
    t0 = time.perf_counter()
    finished = mgr.run()
    wall = time.perf_counter() - t0
    rendered = sum(s.telemetry.frames for s in finished) - viewers  # warm-up
    roll = tick_rollup(mgr.tick_log, warmup_ticks=1)
    return rendered, wall, finished, roll


def run(quick: bool = False):
    frames = 4 if quick else 8
    counts = (1, 2) if quick else (1, 2, 4)
    scene = structured_scene(jax.random.PRNGKey(0), GAUSS)
    cfg = LuminaConfig(capacity=CAPACITY, window=WINDOW)
    rows = []
    for viewers in counts:
        for sequential in (False, True):
            rendered, wall, finished, roll = _serve_once(
                scene, cfg, viewers, frames, sequential)
            fps = rendered / wall if wall > 0 else float('inf')
            cohort_bound = -(-viewers // WINDOW)
            if not sequential:
                assert roll['max_sorts_per_tick'] <= cohort_bound, (
                    f"cohort scheduler regressed: "
                    f"{roll['max_sorts_per_tick']} speculative sorts in one "
                    f"tick with {viewers} viewers, window {WINDOW} "
                    f"(bound ceil(S/window) = {cohort_bound})")
            rows.append({
                'viewers': viewers,
                'mode': 'sequential' if sequential else 'batched',
                'window': WINDOW,
                'frames': rendered,
                'wall_s': wall,
                'fps_total': fps,
                'fps_per_viewer': fps / viewers,
                'hit_rate': sum(s.telemetry.summary()['hit_rate']
                                for s in finished) / viewers,
                'sorts_per_tick': roll['mean_sorts_per_tick'],
                'max_sorts_per_tick': roll['max_sorts_per_tick'],
                'sort_ms': roll['mean_sort_ms'],
                'shade_ms': roll['mean_shade_ms'],
            })
    return rows


def main():
    from benchmarks.common import fmt_rows
    print(fmt_rows(run(), __doc__.strip().splitlines()[0]))


if __name__ == '__main__':
    main()
