"""Fig. 22 — speedup + normalized energy of every Lumina variant over the
mobile-GPU baseline, driven by statistics measured from the functional
pipeline.  Paper targets: S2-GPU ~1.2x, RC-GPU <1x (slowdown!), NRU+GPU
~1.9x, S2-Acc ~3.1x, RC-Acc 1.7-2.7x, Lumina ~4.5x; energy: NRU+GPU -62%,
S2-Acc -79%, RC-Acc -64%, Lumina -81%."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import hwmodel


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 6 if quick else common.FRAMES
    cams = common.vr_trajectory(frames)
    cfg = common.default_cfg()
    stats = common.measured_frames(scene, cams, cfg)
    rows = []
    scenarios = {
        'measured': stats,
        # re-weighted to the paper's Fig. 3 stage mix (real 6M-Gaussian
        # scenes sort far more keys/pixel than our procedural ones)
        'paper-mix': [hwmodel.rescale_to_paper_mix(s) for s in stats],
    }
    for scen, ss in scenarios.items():
        table = hwmodel.evaluate_variants(ss, window=cfg.window)
        for v, m in table.items():
            rows.append({'scenario': scen, 'variant': v,
                         'speedup_x': m['speedup'],
                         'norm_energy': m['norm_energy'],
                         'energy_saving_%': 100 * (1 - m['norm_energy'])})
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.22/25 — speedup + energy vs GPU')


if __name__ == '__main__':
    print(main())
