"""Fig. 3 — normalized execution breakdown (Projection / Sorting /
Rasterization) on the mobile-GPU model, plus the Fig. 4 / Sec. 2.2
characterization: significant fraction, mean iterated Gaussians per pixel,
and the warp-masking fraction (paper: ~10.3% significant, ~69% masked)."""
from __future__ import annotations

import numpy as np

import jax

from benchmarks import common
from repro.core import hwmodel
from repro.data.scenes import structured_scene


def run(quick: bool = False) -> list[dict]:
    frames = 4 if quick else common.FRAMES
    rows = []
    for name, n in (('small', 1500), ('medium', 4000), ('large', 8000)):
        if quick and name == 'large':
            continue
        scene = structured_scene(jax.random.PRNGKey(0), n)
        cams = common.vr_trajectory(frames)
        cfg = common.default_cfg(use_s2=False, use_rc=False)
        stats = common.measured_frames(scene, cams, cfg)
        t = [hwmodel.gpu_stage_times(s) for s in stats]
        tp = float(np.mean([x['projection'] for x in t]))
        ts = float(np.mean([x['sorting'] for x in t]))
        tr = float(np.mean([x['rasterization'] for x in t]))
        tot = tp + ts + tr
        rows.append({
            'scene': f'{name}({n})',
            'projection_%': 100 * tp / tot,
            'sorting_%': 100 * ts / tot,
            'rasterization_%': 100 * tr / tot,
            'sig_frac_%': 100 * float(np.mean([s.sig_fraction for s in stats])),
            'mean_iter_per_px': float(np.mean(
                [s.iterated / s.n_pixels for s in stats])),
            'masked_%': 100 * float(np.mean(
                [s.masked_fraction for s in stats])),
        })
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.3/4 — breakdown + sparsity')


if __name__ == '__main__':
    print(main())
