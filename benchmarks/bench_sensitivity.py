"""Fig. 23 + Fig. 24 — sensitivity studies.

Fig. 23: rendering quality + speedup vs (expanded margin x sharing window).
Fig. 24: quality + rasterization speedup + hit rate vs alpha-record length.
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import hwmodel
from repro.core.metrics import psnr
from repro.core.pipeline import render_frame_baseline


def margin_window_sweep(scene, frames, *, quick=False) -> list[dict]:
    margins = (2, 4) if quick else (2, 4, 8)
    windows = (2, 6) if quick else (2, 6, 12)
    cams = common.vr_trajectory(frames)
    cfg0 = common.quality_cfg(use_s2=False, use_rc=False)
    gts = [render_frame_baseline(scene, cam, cfg0)[0] for cam in cams]
    rows = []
    base_stats = common.measured_frames(scene, cams, cfg0)
    base_t = np.mean([hwmodel.variant_frame_time('GPU', s)
                      for s in base_stats])
    for m in margins:
        for w in windows:
            cfg = common.quality_cfg(margin=m, window=w,
                                     use_s2=True, use_rc=False)
            imgs, stats, _ = common.run_sequence(scene, cams, cfg)
            ps = float(np.mean([float(psnr(i, g))
                                for i, g in zip(imgs, gts)]))
            hstats = common.measured_frames(scene, cams, cfg)
            t = np.mean([hwmodel.variant_frame_time('S2-GPU', s)
                         + hwmodel.gpu_stage_times(s)['sorting'] / w
                         for s in hstats])
            rows.append({'figure': 'Fig23', 'margin': m, 'window': w,
                         'psnr_db': ps, 'speedup_x': float(base_t / t),
                         'k_record': '', 'hit_rate': ''})
    return rows


def krecord_sweep(scene, frames, *, quick=False) -> list[dict]:
    ks = (2, 5) if quick else (1, 2, 3, 5, 8)
    cams = common.vr_trajectory(frames)
    cfg0 = common.quality_cfg(use_s2=False, use_rc=False)
    gts = [render_frame_baseline(scene, cam, cfg0)[0] for cam in cams]
    rows = []
    base_stats = common.measured_frames(scene, cams, cfg0)
    base_r = np.mean([hwmodel.nru_raster_time(s) for s in base_stats])
    for k in ks:
        cfg = common.quality_cfg(k_record=k, use_s2=False, use_rc=True)
        imgs, stats, _ = common.run_sequence(scene, cams, cfg)
        ps = float(np.mean([float(psnr(i, g)) for i, g in zip(imgs, gts)]))
        hit = float(np.mean([float(s.hit_rate) for s in stats[1:]]))
        hstats = common.measured_frames(scene, cams, cfg)
        t = np.mean([hwmodel.nru_raster_time(s, rc=True) for s in hstats])
        rows.append({'figure': 'Fig24', 'margin': '', 'window': '',
                     'psnr_db': ps, 'speedup_x': float(base_r / t),
                     'k_record': k, 'hit_rate': hit})
    return rows


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 4 if quick else 8
    return (margin_window_sweep(scene, frames, quick=quick)
            + krecord_sweep(scene, frames, quick=quick))


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.23/24 — sensitivity')


if __name__ == '__main__':
    print(main())
