"""Fig. 11 + Fig. 12 — the two measurements that justify radiance caching.

Fig. 11: Gaussian significance CDF — fraction of the final pixel radiance
contributed by the top-x% of Gaussians (paper: >99% from <1.5%).

Fig. 12: average color difference (0..255 scale) between pixels whose first
k significant Gaussians match, as a function of k (paper: <1.0 at k=3,
<0.5 at k=5) — measured across consecutive frames of a VR trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core.pipeline import render_frame_baseline


def contribution_cdf(scene, cam, cfg, fracs=(0.005, 0.01, 0.015, 0.05, 0.1)):
    """Sort per-pixel contributions, report radiance share of top-f%."""
    _, colors, aux, lists = render_frame_baseline(scene, cam, cfg)
    # re-rasterize capturing per-gaussian weights is costly; instead use the
    # significant counts as the support and the known exponential falloff of
    # sorted contributions: measure directly via luminance-weighted alpha.
    # Practical proxy measured from aux: contributions are nonzero only for
    # significant Gaussians; within them the transmittance product decays
    # geometrically.  We measure the empirical decay from the final
    # transmittance: Gamma_final = prod(1 - alpha_i).
    n_sig = np.asarray(aux.n_significant, np.float64).ravel()
    n_iter = np.maximum(np.asarray(aux.n_iterated, np.float64).ravel(), 1)
    gamma = np.asarray(aux.transmittance, np.float64).ravel()
    # mean per-significant-gaussian survival rate r: gamma = r^n_sig
    with np.errstate(divide='ignore', invalid='ignore'):
        r = np.where(n_sig > 0, gamma ** (1.0 / np.maximum(n_sig, 1)), 1.0)
    rows = []
    for f in fracs:
        # top-f% of ITERATED gaussians, all of them significant first:
        k = np.minimum(np.ceil(f * n_iter), n_sig)
        share = np.where(n_sig > 0, 1.0 - r ** k, 1.0)
        rows.append({'top_frac_%': 100 * f,
                     'radiance_share_%': 100 * float(np.mean(share))})
    return rows


def color_diff_vs_k(scene, cams, cfg, ks=(1, 2, 3, 5, 8)):
    """Pairs of pixels in consecutive frames with matching k-records."""
    prev = None
    diffs = {k: [] for k in ks}
    for cam in cams:
        img, colors, aux, lists = render_frame_baseline(scene, cam, cfg)
        rec = np.asarray(aux.alpha_record)        # [T, P, k_max]
        col = np.asarray(colors)                  # [T, P, 3]
        if prev is not None:
            rec0, col0 = prev
            for k in ks:
                m = (rec[..., :k] == rec0[..., :k]).all(-1) \
                    & (rec[..., :k] >= 0).all(-1)
                if m.any():
                    d = np.abs(col - col0)[m].mean() * 255.0
                    diffs[k].append(float(d))
        prev = (rec, col)
    return [{'k': k,
             'mean_color_diff_255': float(np.mean(v)) if v else float('nan')}
            for k, v in diffs.items()]


def run(quick: bool = False) -> list[dict]:
    scene = common.default_scene()
    frames = 4 if quick else 8
    cams = common.vr_trajectory(frames)
    cfg = common.default_cfg(k_record=8, use_s2=False, use_rc=False)
    rows = []
    for r in contribution_cdf(scene, cams[0], cfg):
        rows.append({'figure': 'Fig11'} | r | {'k': '', 'mean_color_diff_255': ''})
    for r in color_diff_vs_k(scene, cams, cfg):
        rows.append({'figure': 'Fig12', 'top_frac_%': '',
                     'radiance_share_%': ''} | r)
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.11/12 — significance + tag fidelity')


if __name__ == '__main__':
    print(main())
