"""Fig. 21 (+ Fig. 13) — cache-aware fine-tuning with the scale-constrained
loss.  A scene seeded with oversized Gaussians (the Fig. 13 failure mode) is
fine-tuned twice — alpha=0 (plain 3DGS loss) and alpha>0 (Eqn. 4) — then
RC-only quality and cache hit rate are compared.  Paper: +0.6 dB PSNR at a
small hit-rate cost."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common
from repro.core.finetune import FinetuneConfig, finetune
from repro.core.metrics import psnr
from repro.core.pipeline import render_frame_baseline
from repro.data.scenes import structured_scene


def run(quick: bool = False) -> list[dict]:
    n = 1200 if quick else 2000
    steps = 40 if quick else 160
    frames = 4 if quick else 8
    img = 96
    key = jax.random.PRNGKey(3)

    # ground-truth scene (well-conditioned) renders the target images
    gt_scene = structured_scene(key, n)
    cams = common.real_trajectory(frames, img=img)   # 30 FPS: larger motion
    cfg_r = common.default_cfg(capacity=384, use_s2=False, use_rc=False)
    gts = [render_frame_baseline(gt_scene, c, cfg_r)[0] for c in cams]

    # corrupted starting point: oversized Gaussians (Fig. 13 failure mode)
    start = structured_scene(key, n, large_gaussian_frac=0.25)

    rows = []
    for name, alpha in (('no_Lscale', 0.0), ('with_Lscale', 8.0)):
        fcfg = FinetuneConfig(scale_alpha=alpha, scale_theta=0.03)
        tuned, hist = finetune(start, cams, gts, fcfg, cfg_r, steps)
        # evaluate RC-only on the tuned scene
        cfg_rc = common.default_cfg(capacity=384, use_s2=False, use_rc=True)
        imgs, stats, _ = common.run_sequence(tuned, cams, cfg_rc)
        exact = [render_frame_baseline(tuned, c, cfg_r)[0] for c in cams]
        ps = float(np.mean([float(psnr(i, g)) for i, g in zip(imgs, gts)]))
        ps_vs_exact = float(np.mean(
            [float(psnr(i, e)) for i, e in zip(imgs, exact)]))
        hit = float(np.mean([float(s.hit_rate) for s in stats[1:]]))
        rows.append({'finetune': name, 'alpha': alpha,
                     'rc_psnr_vs_gt_db': ps,
                     'rc_psnr_vs_exact_db': ps_vs_exact,
                     'hit_rate': hit,
                     'final_train_loss': float(hist[-1].loss)})
    return rows


def main(quick: bool = False) -> str:
    return common.fmt_rows(run(quick), 'Fig.21 — cache-aware fine-tuning')


if __name__ == '__main__':
    print(main())
