"""Shared benchmark scaffolding: scenes, trajectories, measured frames."""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hwmodel, radiance_cache as rc
from repro.core.camera import Camera
from repro.core.groups import num_groups
from repro.core.pipeline import (LuminaConfig, LuminSys,
                                 render_frame_baseline)
from repro.data.scenes import structured_scene
from repro.data.trajectory import orbit_trajectory

# Benchmark scale: small enough for the 1-core CPU container, big enough
# that sparsity/coherence statistics are meaningful.
N_GAUSS = 4000
IMG = 128
CAPACITY = 512        # speedup/statistics benches (realistic budget)
CAPACITY_EXACT = 1536  # quality benches: ample so per-tile truncation never
                       # confounds S^2/RC quality deltas (see EXPERIMENTS.md)
FRAMES = 12


def default_scene(key=0, **kw):
    return structured_scene(jax.random.PRNGKey(key), N_GAUSS, **kw)


def vr_trajectory(frames=FRAMES, *, fps=90.0, img=IMG):
    return orbit_trajectory(frames, fps=fps, width=img, height_px=img)


def real_trajectory(frames=FRAMES, *, img=IMG):
    """30-FPS capture: 3x larger inter-frame motion (paper Sec. 5)."""
    return orbit_trajectory(frames, fps=30.0, width=img, height_px=img)


def default_cfg(**kw) -> LuminaConfig:
    base = dict(capacity=CAPACITY, window=6, margin=4)
    base.update(kw)
    return LuminaConfig(**base)


def quality_cfg(**kw) -> LuminaConfig:
    base = dict(capacity=CAPACITY_EXACT, window=6, margin=4)
    base.update(kw)
    return LuminaConfig(**base)


def run_sequence(scene, cams, cfg: LuminaConfig):
    """Drive LuminSys over a trajectory; returns (images, stats, gt images)."""
    sys_ = LuminSys(scene, cfg, cams[0])
    images, stats, gts = [], [], []
    for cam in cams:
        img, st = sys_.step(cam)
        images.append(img)
        gt, _, _, _ = render_frame_baseline(scene, cam, cfg)
        gts.append(gt)
        stats.append(st)
    return images, stats, gts


def measured_frames(scene, cams, cfg: LuminaConfig):
    """Per-frame FrameHWStats for the hardware models (baseline pipeline
    stats + the LuminSys hit rates of the same frames)."""
    sys_ = LuminSys(scene, cfg, cams[0])
    out = []
    for i, cam in enumerate(cams):
        _, st = sys_.step(cam)
        _, colors, aux, lists = render_frame_baseline(scene, cam, cfg)
        sorted_flag = 1.0 / cfg.window if cfg.use_s2 else 1.0
        out.append(hwmodel.measure_frame(
            lists, aux, hit_rate=float(st.hit_rate),
            sorted_this_frame=sorted_flag))
    return out


def fmt_rows(rows: list[dict], title: str) -> str:
    """Heterogeneous-tolerant table: columns are the union across rows in
    first-appearance order (e.g. streaming rows carry stream_* fields the
    plain rows lack); absent cells render blank."""
    if not rows:
        return f'== {title} ==\n(no rows)'
    cols = list(dict.fromkeys(c for r in rows for c in r))
    w = {c: max(len(c), max(len(_f(r.get(c, ''))) for r in rows))
         for c in cols}
    lines = [f'== {title} ==',
             '  '.join(c.ljust(w[c]) for c in cols)]
    for r in rows:
        lines.append('  '.join(_f(r.get(c, '')).ljust(w[c]) for c in cols))
    return '\n'.join(lines)


def _f(v) -> str:
    if isinstance(v, float):
        return f'{v:.4g}'
    return str(v)
