"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only NAME]

Suites:
  breakdown    Fig. 3/4   execution breakdown + sparsity characterization
  sparsity     Fig. 11/12 significance CDF + tag fidelity vs k
  quality      Fig. 20    PSNR/SSIM of S2/RC/Lumina/DS-2 vs exact baseline
  speedup      Fig. 22/25 variant speedup + energy (incl. GSCore)
  sensitivity  Fig. 23/24 margin x window, alpha-record length
  finetune     Fig. 21/13 scale-constrained loss
  kernel       --         Pallas chunk-early-exit savings
  serve        --         multi-viewer throughput, batched vs sequential
"""
from __future__ import annotations

import argparse
import json
import time
import traceback
from pathlib import Path

SUITES = ('breakdown', 'sparsity', 'quality', 'speedup', 'sensitivity',
          'finetune', 'kernel', 'serve')

# Suites whose rows are additionally written as machine-readable
# BENCH_<name>.json at the repo root — the perf trajectory other sessions
# diff against (experiments/bench/ keeps the full per-run archive).
TRACKED = ('serve', 'kernel')
REPO_ROOT = Path(__file__).resolve().parent.parent


def _render(mod, rows) -> str:
    from benchmarks import common
    title = mod.__doc__.strip().splitlines()[0]
    return common.fmt_rows(rows, title)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument('--quick', action='store_true')
    ap.add_argument('--only', default='')
    ap.add_argument('--out', default='experiments/bench')
    args = ap.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    failures = []
    for name in SUITES:
        if args.only and name != args.only:
            continue
        mod = __import__(f'benchmarks.bench_{name}', fromlist=['run', 'main'])
        t0 = time.time()
        try:
            rows = mod.run(quick=args.quick)
            print(_render(mod, rows))
            print(f'[{name}: {time.time() - t0:.1f}s]\n')
            with open(out_dir / f'{name}.json', 'w') as f:
                json.dump(rows, f, indent=1, default=str)
            if name in TRACKED:
                payload = {'suite': name, 'quick': bool(args.quick),
                           'wall_s': round(time.time() - t0, 2),
                           'rows': rows}
                with open(REPO_ROOT / f'BENCH_{name}.json', 'w') as f:
                    json.dump(payload, f, indent=1, default=str)
        except Exception:
            failures.append(name)
            print(f'== {name} FAILED ==')
            traceback.print_exc()
    if failures:
        raise SystemExit(f'benchmark suites failed: {failures}')


if __name__ == '__main__':
    main()
